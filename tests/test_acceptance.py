"""Immigrant-acceptance engine (core.acceptance): registry, the 'always'
bit-for-bit anchor, policy semantics, the per-island receive gate, the host
PoolServer mirror, diversity preservation, degenerate-async equivalence,
and SPMD replica consistency (subprocess-isolated on 8 fake devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AcceptanceConfig, AsyncConfig, EAConfig,
                        MigrationConfig, PoolServer, acceptance, make_onemax,
                        make_trap, run_fused, run_fused_async)
from repro.core import pool as pool_lib
from repro.core.pool import NEG_INF
from repro.core.types import GenomeSpec, PoolState

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ALL_POLICIES = ("always", "elitist", "crowding", "dedup")
GEN = GenomeSpec("binary", 8)
CFG = EAConfig(max_pop=32, min_pop=16, generations_per_epoch=5,
               mutation_rate=0.05)


def _legacy_pool_put_batch(pool, genomes, fitness, valid=None):
    """The pre-engine pool_put_batch, verbatim — the bit-for-bit anchor."""
    k = genomes.shape[0]
    cap = pool.genomes.shape[0]
    if valid is None:
        valid = jnp.ones((k,), bool)
    if k > cap:
        score = jnp.where(valid, fitness, NEG_INF)
        _, top = jax.lax.top_k(score, cap)
        genomes, fitness, valid = genomes[top], fitness[top], valid[top]
        k = cap
    order = jnp.argsort(~valid, stable=True)
    genomes, fitness = genomes[order], fitness[order]
    n_valid = valid.sum().astype(jnp.int32)
    slots = (pool.ptr + jnp.arange(k, dtype=jnp.int32)) % cap
    write = jnp.arange(k) < n_valid
    safe_slots = jnp.where(write, slots, cap)
    new_genomes = pool.genomes.at[safe_slots].set(
        genomes.astype(pool.genomes.dtype), mode="drop")
    new_fitness = pool.fitness.at[safe_slots].set(fitness, mode="drop")
    return PoolState(
        genomes=new_genomes, fitness=new_fitness,
        ptr=(pool.ptr + n_valid) % cap,
        count=jnp.minimum(pool.count + n_valid, cap))


def _mk_pool(fits, cap=None, gen=GEN):
    """A pool whose first len(fits) slots hold identifiable residents."""
    cap = cap or len(fits)
    pool = pool_lib.pool_init(cap, gen)
    g = (jnp.arange(len(fits), dtype=jnp.int8)[:, None]
         * jnp.ones((len(fits), gen.length), jnp.int8))
    return pool_lib.pool_put_batch(pool, g, jnp.asarray(fits, jnp.float32))


class TestRegistry:
    def test_builtins_registered(self):
        assert set(ALL_POLICIES) <= set(acceptance.available_policies())

    def test_unknown_policy_raises(self):
        with pytest.raises(KeyError, match="unknown acceptance policy"):
            acceptance.get_policy("no_such_policy")

    def test_custom_registration_dispatches(self):
        @acceptance.register_policy("_test_reject_all")
        def reject_all(pool_g, pool_f, cand_g, cand_f, valid, rng, *,
                       ptr, count, acc):
            cap = pool_f.shape[0]
            return (jnp.full((cand_f.shape[0],), cap, jnp.int32), ptr,
                    count)

        try:
            pool = pool_lib.pool_init(4, GEN)
            out = pool_lib.pool_put_batch(
                pool, jnp.ones((2, 8), jnp.int8), jnp.array([1.0, 2.0]),
                acc=AcceptanceConfig(policy="_test_reject_all"))
            assert int(out.count) == 0
            assert np.isneginf(np.asarray(out.fitness)).all()
        finally:
            del acceptance.ACCEPTANCE_POLICIES["_test_reject_all"]

    def test_config_validation(self):
        with pytest.raises(ValueError):
            AcceptanceConfig(epsilon=-1.0)
        with pytest.raises(ValueError):
            AcceptanceConfig(metric="cosine")


class TestAlwaysBitForBit:
    """AcceptanceConfig(policy='always') == the legacy ring insert,
    bit for bit, over random batches, valid masks and overflow."""

    @pytest.mark.parametrize("kind", ["binary", "float"])
    def test_random_streams(self, kind):
        rngs = np.random.default_rng(0 if kind == "binary" else 1)
        gen = GenomeSpec(kind, 6)
        for _ in range(40):
            cap = int(rngs.integers(1, 9))
            k = int(rngs.integers(1, 14))    # includes k > cap overflow
            ref = pool_lib.pool_init(cap, gen)
            got = pool_lib.pool_init(cap, gen)
            for step in range(3):
                if kind == "binary":
                    g = rngs.integers(0, 2, (k, 6)).astype(np.int8)
                else:
                    g = rngs.normal(size=(k, 6)).astype(np.float32)
                f = rngs.normal(size=(k,)).astype(np.float32)
                valid = (None if step == 0
                         else jnp.asarray(rngs.random(k) < 0.7))
                ref = _legacy_pool_put_batch(ref, jnp.asarray(g),
                                             jnp.asarray(f), valid)
                got = pool_lib.pool_put_batch(
                    got, jnp.asarray(g), jnp.asarray(f), valid,
                    acc=AcceptanceConfig(policy="always"),
                    rng=jax.random.key(step))
                for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))

    def test_default_acc_is_always(self):
        """pool_put_batch with no acc kwarg is the legacy path."""
        g = jnp.ones((3, 8), jnp.int8)
        f = jnp.array([1.0, 2.0, 3.0])
        ref = _legacy_pool_put_batch(pool_lib.pool_init(4, GEN), g, f)
        got = pool_lib.pool_put_batch(pool_lib.pool_init(4, GEN), g, f)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestElitist:
    ACC = AcceptanceConfig(policy="elitist")

    def test_fills_empty_pool_first(self):
        pool = pool_lib.pool_init(4, GEN)
        pool = pool_lib.pool_put_batch(
            pool, jnp.ones((2, 8), jnp.int8), jnp.array([5.0, 3.0]),
            acc=self.ACC)
        assert int(pool.count) == 2
        kept = sorted(x for x in np.asarray(pool.fitness).tolist()
                      if np.isfinite(x))
        assert kept == [3.0, 5.0]

    def test_replaces_worst_only_if_better(self):
        pool = _mk_pool([5.0, 1.0, 3.0])
        out = pool_lib.pool_put_batch(
            pool, jnp.full((1, 8), 9, jnp.int8), jnp.array([2.0]),
            acc=self.ACC)
        fits = sorted(np.asarray(out.fitness).tolist())
        assert fits == [2.0, 3.0, 5.0]      # the 1.0 resident lost
        assert int(out.count) == 3
        out2 = pool_lib.pool_put_batch(
            out, jnp.full((1, 8), 9, jnp.int8), jnp.array([1.5]),
            acc=self.ACC)
        assert sorted(np.asarray(out2.fitness).tolist()) == fits  # rejected

    def test_batch_challenges_ranked_worst(self):
        """Best candidate vs worst resident, 2nd vs 2nd-worst, ..."""
        pool = _mk_pool([0.0, 5.0])
        out = pool_lib.pool_put_batch(
            pool, jnp.full((2, 8), 7, jnp.int8), jnp.array([1.0, 9.0]),
            acc=self.ACC)
        # 9.0 beats worst (0.0); 1.0 challenges 5.0 and loses
        assert sorted(np.asarray(out.fitness).tolist()) == [5.0, 9.0]


class TestCrowding:
    ACC = AcceptanceConfig(policy="crowding")

    def test_replaces_nearest_if_fitter(self):
        gen = GenomeSpec("binary", 4)
        pool = pool_lib.pool_init(2, gen)
        pool = pool_lib.pool_put_batch(
            pool, jnp.asarray([[0, 0, 0, 0], [1, 1, 1, 1]], jnp.int8),
            jnp.array([1.0, 2.0]))
        cand = jnp.asarray([[1, 1, 1, 0]], jnp.int8)   # nearest: all-ones
        out = pool_lib.pool_put_batch(pool, cand, jnp.array([5.0]),
                                      acc=self.ACC)
        fits = np.asarray(out.fitness).tolist()
        assert fits == [1.0, 5.0]           # slot 1 (nearest) was replaced

    def test_nearest_not_fitter_is_rejected(self):
        gen = GenomeSpec("binary", 4)
        pool = pool_lib.pool_init(2, gen)
        pool = pool_lib.pool_put_batch(
            pool, jnp.asarray([[0, 0, 0, 0], [1, 1, 1, 1]], jnp.int8),
            jnp.array([1.0, 9.0]))
        cand = jnp.asarray([[1, 1, 1, 0]], jnp.int8)   # nearest holds 9.0
        out = pool_lib.pool_put_batch(pool, cand, jnp.array([5.0]),
                                      acc=self.ACC)
        assert np.asarray(out.fitness).tolist() == [1.0, 9.0]

    def test_conflict_resolved_to_fittest_candidate(self):
        gen = GenomeSpec("binary", 4)
        pool = pool_lib.pool_init(1, gen)
        pool = pool_lib.pool_put_batch(
            pool, jnp.asarray([[1, 1, 1, 1]], jnp.int8), jnp.array([1.0]))
        cands = jnp.asarray([[1, 1, 1, 0], [1, 1, 0, 1]], jnp.int8)
        out = pool_lib.pool_put_batch(pool, cands, jnp.array([3.0, 7.0]),
                                      acc=self.ACC)
        assert np.asarray(out.fitness).tolist() == [7.0]
        np.testing.assert_array_equal(np.asarray(out.genomes[0]),
                                      [1, 1, 0, 1])

    def test_diversity_never_collapses_below_always(self):
        """The headline property: on a deceptive trap run the crowding
        pool keeps at least the accept-everything baseline's diversity."""
        from benchmarks.pool_throughput import _mean_pairwise_distance
        problem = make_trap(n_traps=6, l=4)
        div = {}
        for pol in ("always", "crowding"):
            mig = MigrationConfig(
                pool_capacity=16, topology="pool",
                acceptance=AcceptanceConfig(policy=pol))
            _, pool, _ = run_fused(problem, CFG, mig, n_islands=8,
                                   max_epochs=8, rng=jax.random.key(3),
                                   w2=True)
            count = int(np.asarray(pool.count))
            assert count >= 2
            div[pol] = _mean_pairwise_distance(
                np.asarray(pool.genomes)[:count])
        assert div["crowding"] >= div["always"]


class TestDedup:
    def test_rejects_epsilon_duplicates(self):
        gen = GenomeSpec("binary", 4)
        pool = pool_lib.pool_init(4, gen)
        pool = pool_lib.pool_put_batch(
            pool, jnp.asarray([[1, 1, 0, 0]], jnp.int8), jnp.array([5.0]))
        acc = AcceptanceConfig(policy="dedup", epsilon=1.0)
        # hamming distance 1 from the resident -> rejected despite fitter
        out = pool_lib.pool_put_batch(
            pool, jnp.asarray([[1, 1, 1, 0]], jnp.int8), jnp.array([9.0]),
            acc=acc)
        assert int(out.count) == 1
        assert float(out.fitness[0]) == 5.0
        # distance 2 > epsilon -> accepted into a free slot
        out = pool_lib.pool_put_batch(
            pool, jnp.asarray([[0, 0, 1, 1]], jnp.int8), jnp.array([9.0]),
            acc=acc)
        assert int(out.count) == 2

    def test_epsilon_zero_rejects_exact_clones_only(self):
        gen = GenomeSpec("binary", 4)
        pool = pool_lib.pool_init(4, gen)
        pool = pool_lib.pool_put_batch(
            pool, jnp.asarray([[1, 0, 1, 0]], jnp.int8), jnp.array([5.0]))
        acc = AcceptanceConfig(policy="dedup")
        clone = pool_lib.pool_put_batch(
            pool, jnp.asarray([[1, 0, 1, 0]], jnp.int8), jnp.array([9.0]),
            acc=acc)
        assert int(clone.count) == 1                 # exact clone rejected
        near = pool_lib.pool_put_batch(
            pool, jnp.asarray([[1, 0, 1, 1]], jnp.int8), jnp.array([9.0]),
            acc=acc)
        assert int(near.count) == 2                  # distance 1 accepted

    def test_rejects_duplicates_within_one_batch(self):
        """Two epsilon-close candidates in a single PUT batch: only the
        first survives — matching the host mirror's one-at-a-time stream
        (which would make the first a resident before the second arrives)."""
        gen = GenomeSpec("binary", 4)
        pool = pool_lib.pool_init(4, gen)
        cands = jnp.asarray([[1, 0, 1, 0], [1, 0, 1, 0], [0, 1, 0, 1]],
                            jnp.int8)
        out = pool_lib.pool_put_batch(
            pool, cands, jnp.array([5.0, 9.0, 7.0]),
            acc=AcceptanceConfig(policy="dedup"))
        assert int(out.count) == 2               # the clone was rejected
        kept = sorted(x for x in np.asarray(out.fitness).tolist()
                      if np.isfinite(x))
        assert kept == [5.0, 7.0]

    def test_survivors_fall_through_to_elitist(self):
        gen = GenomeSpec("binary", 4)
        pool = pool_lib.pool_init(1, gen)
        pool = pool_lib.pool_put_batch(
            pool, jnp.asarray([[1, 1, 1, 1]], jnp.int8), jnp.array([5.0]))
        acc = AcceptanceConfig(policy="dedup")
        worse = pool_lib.pool_put_batch(
            pool, jnp.asarray([[0, 0, 0, 0]], jnp.int8), jnp.array([2.0]),
            acc=acc)
        assert float(worse.fitness[0]) == 5.0        # not fitter -> reject
        better = pool_lib.pool_put_batch(
            pool, jnp.asarray([[0, 0, 0, 0]], jnp.int8), jnp.array([7.0]),
            acc=acc)
        assert float(better.fitness[0]) == 7.0


class TestReceiveGate:
    def _dest(self, fits):
        n = len(fits)
        g = (jnp.arange(n, dtype=jnp.int8)[:, None]
             * jnp.ones((n, GEN.length), jnp.int8))
        return g, jnp.asarray(fits, jnp.float32)

    def test_elitist_gate_rejects_not_fitter(self):
        dg, df = self._dest([5.0, 1.0])
        imm_g = jnp.ones((2, GEN.length), jnp.int8)
        imm_f = jnp.array([3.0, 3.0])
        out = acceptance.gate_immigrants(
            dg, df, imm_g, imm_f, jax.random.key(0),
            AcceptanceConfig(policy="elitist"))
        assert np.isneginf(float(out[0]))            # 3.0 <= 5.0 rejected
        assert float(out[1]) == 3.0                  # 3.0 > 1.0 accepted

    def test_dedup_gate_rejects_clone_of_own_best(self):
        dg, df = self._dest([5.0, 5.0])
        imm_f = jnp.array([9.0, 9.0])
        imm_g = jnp.stack([dg[0], jnp.full((GEN.length,), 7, jnp.int8)])
        out = acceptance.gate_immigrants(
            dg, df, imm_g, imm_f, jax.random.key(0),
            AcceptanceConfig(policy="dedup"))
        assert np.isneginf(float(out[0]))            # clone of own best
        assert float(out[1]) == 9.0

    def test_neg_inf_immigrants_stay_rejected(self):
        dg, df = self._dest([1.0])
        out = acceptance.gate_immigrants(
            dg, df, jnp.ones((1, GEN.length), jnp.int8),
            jnp.asarray([NEG_INF]), jax.random.key(0),
            AcceptanceConfig(policy="crowding"))
        assert np.isneginf(float(out[0]))

    @pytest.mark.parametrize("topo", ["pool", "ring", "broadcast_best"])
    def test_migrate_dispatches_gate_for_every_topology(self, topo):
        """With an elitist acceptance, deliveries not fitter than the
        destination's own best arrive as -inf through migrate()."""
        from repro.core import migration
        n = 4
        g = (jnp.arange(n, dtype=jnp.int8)[:, None]
             * jnp.ones((n, GEN.length), jnp.int8))
        f = jnp.arange(n, dtype=jnp.float32)
        mig = MigrationConfig(topology=topo, pool_capacity=8,
                              acceptance=AcceptanceConfig(policy="elitist"))
        _, _, imm_f = migration.migrate(
            pool_lib.pool_init(8, GEN), g, f, jax.random.key(0), mig,
            epoch=0)
        imm_f = np.asarray(imm_f)
        # every finite delivery is strictly fitter than the dest's own best
        finite = np.isfinite(imm_f)
        assert (imm_f[finite] > np.asarray(f)[finite]).all()
        # the worst island (island 0 has best -inf-adjacent 0.0) can still
        # receive; the globally best island can never hear a fitter genome
        assert np.isneginf(imm_f[np.argmax(np.asarray(f))])


class TestHostMirror:
    """Device pool and host PoolServer make the same decisions for the
    same single-candidate stream."""

    @pytest.mark.parametrize("policy", ["elitist", "crowding", "dedup"])
    def test_same_resident_multiset(self, policy):
        cap = 4
        acc = AcceptanceConfig(policy=policy, epsilon=0.0)
        server = PoolServer(capacity=cap, acceptance=acc)
        pool = pool_lib.pool_init(cap, GEN)
        rngs = np.random.default_rng(5)
        for i in range(32):
            g = rngs.integers(0, 2, GEN.length).astype(np.int8)
            f = float(np.round(rngs.normal(), 3))
            server.put(g, f)
            pool = pool_lib.pool_put_batch(
                pool, jnp.asarray(g)[None], jnp.asarray([f]), acc=acc)
        dev = sorted(x for x in np.asarray(pool.fitness).tolist()
                     if np.isfinite(x))
        host = sorted(e.fitness for e in server._entries)
        assert dev == pytest.approx(host)

    def test_host_rejections_counted(self):
        acc = AcceptanceConfig(policy="elitist")
        server = PoolServer(capacity=1, acceptance=acc)
        server.put(np.zeros(4, np.int8), 5.0)
        server.put(np.ones(4, np.int8), 1.0)         # worse -> rejected
        st = server.stats()
        assert st["rejected"] == 1 and st["size"] == 1
        assert st["best_fitness"] == 5.0

    def test_unknown_policy_host_mirror_raises(self):
        with pytest.raises(KeyError, match="no host mirror"):
            acceptance.host_accept(
                np.zeros((1, 4)), np.zeros(1), np.zeros(4), 1.0,
                AcceptanceConfig(policy="nope"), capacity=1)


class TestDegenerateAsyncEquivalence:
    """The PR-2 anchor survives the new axis: degenerate async == sync,
    bit for bit, under every acceptance policy."""

    @pytest.mark.parametrize("policy", ALL_POLICIES)
    @pytest.mark.parametrize("topo", ["pool", "ring"])
    def test_fused_bit_for_bit(self, policy, topo):
        problem = make_onemax(24)
        mig = MigrationConfig(topology=topo, pool_capacity=8,
                              acceptance=AcceptanceConfig(policy=policy))
        sync = run_fused(problem, CFG, mig, n_islands=6, max_epochs=4,
                         rng=jax.random.key(0), w2=True)
        asyn = run_fused_async(problem, CFG, mig, AsyncConfig(),
                               n_islands=6, max_ticks=4,
                               rng=jax.random.key(0), w2=True)
        for a, b in zip(jax.tree.leaves(sync[:2]),
                        jax.tree.leaves(asyn[:2])):
            if hasattr(a, "dtype") and jax.dtypes.issubdtype(
                    a.dtype, jax.dtypes.prng_key):
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core import (AcceptanceConfig, EAConfig, MigrationConfig,
                            make_onemax, migration)
    from repro.core import pool as pool_lib
    from repro.core.sharded import run_fused_sharded
    from repro.core.types import GenomeSpec, PoolState
    from repro.launch.mesh import make_host_mesh

    AX = "islands"
    mesh = make_host_mesh()
    N = mesh.shape[AX] * 2
    GEN = GenomeSpec("binary", 8)
    out = {}

    g = (jnp.arange(N, dtype=jnp.int8)[:, None]
         * jnp.ones((N, GEN.length), jnp.int8))
    f = jnp.arange(N, dtype=jnp.float32)
    POOL_SPEC = PoolState(*[P()] * len(PoolState._fields))

    def run_policy(policy, available=True, cap=8):
        mig = MigrationConfig(topology="pool", pool_capacity=cap,
                              acceptance=AcceptanceConfig(policy=policy))

        def body(pool, bg, bf, rng):
            pool, ig, if_ = migration.migrate(
                pool, bg, bf, rng, mig, axis=AX, epoch=0,
                available=available)
            return jax.tree.map(lambda x: x[None], pool), ig, if_

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(POOL_SPEC, P(AX), P(AX), P()),
            out_specs=(PoolState(*[P(AX)] * len(PoolState._fields)),
                       P(AX), P(AX)),
            check=False)
        return fn(pool_lib.pool_init(cap, GEN), g, f, jax.random.key(7))

    # every policy's pool replica is identical on every shard (the policy
    # ran on the all_gather'd candidates with a pre-fold key)
    for policy in ("always", "elitist", "crowding", "dedup"):
        pools, ig, if_ = run_policy(policy)
        out[f"{policy}_replicas_equal"] = all(
            bool((np.asarray(x) == np.asarray(x)[0]).all())
            for x in jax.tree.leaves(pools))

    # elitist on a small pool keeps the globally best contributions,
    # identically on every replica
    pools, _, _ = run_policy("elitist", cap=4)
    fits = np.asarray(pools.fitness)[0]
    out["elitist_keeps_top4"] = sorted(fits.tolist()) == [
        float(N - 4), float(N - 3), float(N - 2), float(N - 1)]

    # the sharded fused driver runs every policy end to end
    cfg = EAConfig(max_pop=32, min_pop=16, generations_per_epoch=3,
                   mutation_rate=0.05)
    for policy in ("elitist", "crowding", "dedup"):
        mig = MigrationConfig(topology="pool", pool_capacity=16,
                              acceptance=AcceptanceConfig(policy=policy))
        isl, pool, ep = run_fused_sharded(
            mesh, make_onemax(24), cfg, mig, islands_per_shard=2,
            max_epochs=3, rng=jax.random.key(0))
        out[f"{policy}_sharded_driver"] = bool(
            np.isfinite(float(isl.best_fitness.max())))
    print(json.dumps(out))
""")


def test_spmd_acceptance_replica_consistency():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                          cwd=REPO, capture_output=True, text=True,
                          timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    bad = {k: v for k, v in out.items() if v is not True}
    assert not bad, f"failed SPMD acceptance properties: {bad}"
