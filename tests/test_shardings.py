"""Sharding rules: divisibility, no duplicate mesh axes, ZeRO-1, batch."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import shardings as sh
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh16():
    # fake (data=1, model=1) won't exercise divisibility; build an abstract
    # 16x16 mesh from the single CPU device via AbstractMesh
    from repro.compat import abstract_mesh
    return abstract_mesh((16, 16), ("data", "model"))


def _pspecs(arch, mesh, mode):
    cfg = get_config(arch)
    m = build_model(cfg)
    return cfg, m, sh.tree_pspecs(m.param_axes(), m.abstract_params(), cfg,
                                  mesh, mode)


class TestParamSpecs:
    @pytest.mark.parametrize("arch", ["yi-9b", "qwen3-32b", "granite-34b",
                                      "dbrx-132b", "rwkv6-3b", "hymba-1.5b"])
    def test_no_duplicate_axes(self, arch, mesh16):
        cfg, m, specs = _pspecs(arch, mesh16, "serve")
        for spec in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P)):
            flat = []
            for e in spec:
                if isinstance(e, tuple):
                    flat.extend(e)
                elif e is not None:
                    flat.append(e)
            assert len(flat) == len(set(flat)), (arch, spec)

    def test_train_mode_ff_model_sharded(self, mesh16):
        cfg, m, specs = _pspecs("yi-9b", mesh16, "train")
        wg = specs["segments"][0][0]["ffn"]["wg"]
        assert wg == P(None, None, "model")     # (layers, d, ff)

    def test_serve_mode_fully_sharded(self, mesh16):
        cfg, m, specs = _pspecs("yi-9b", mesh16, "serve")
        wg = specs["segments"][0][0]["ffn"]["wg"]
        assert wg[1] == "data" and wg[2] == "model"

    def test_vocab_sharded_after_padding(self, mesh16):
        cfg, m, specs = _pspecs("qwen3-32b", mesh16, "train")
        assert specs["embed"][0] == "model"
        assert cfg.padded_vocab % 256 == 0

    def test_indivisible_replicated(self, mesh16):
        cfg, m, specs = _pspecs("hymba-1.5b", mesh16, "train")
        # 25 q-heads * 64 = 1600 % 16 == 0 -> shardable; kv 5*64=320 % 16 = 0
        att = specs["segments"][1][0]["mixer"]["attn"]
        assert att["wq"][-1] == "model"


class TestZero1:
    def test_moments_pick_up_data_axis(self, mesh16):
        shape = (48, 4096, 11008)
        spec = P(None, None, "model")
        z = sh.zero1_pspec(spec, shape, mesh16)
        assert z == P(None, "data", "model")

    def test_no_candidate_stays(self, mesh16):
        z = sh.zero1_pspec(P("model"), (16,), mesh16)
        assert z == P("model")


class TestBatch:
    def test_batch_over_dp(self, mesh16):
        specs = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
                 "index": jax.ShapeDtypeStruct((), jnp.int32)}
        ps = sh.batch_pspecs(specs, mesh16)
        assert ps["tokens"] == P("data", None)
        assert ps["index"] == P()

    def test_indivisible_batch_replicates(self, mesh16):
        specs = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
        ps = sh.batch_pspecs(specs, mesh16)
        assert ps["tokens"] == P(None, None)
