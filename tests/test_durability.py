"""Durable experiment lifecycle (ISSUE 8).

* segmented fused drivers (sync / async / sharded) are bit-for-bit the
  monolithic scan, and a resume from the last surviving snapshot
  reproduces the uninterrupted seeded run exactly;
* elastic resume: an 8-island checkpoint restores into a 16-island run
  (grow seeds from the pool, uuids from the monotonic watermark) and into
  a smaller one (shrink);
* the PoolServer journal is a write-ahead log: a restarted server
  rehydrates entries/seq/cursors/stats and preserves exactly-once
  ``get_since`` delivery — including through a torn final line;
* Checkpointer regressions: wait() drains errors instead of re-raising
  forever, save_async prunes finished writer threads, stale ``.tmp``
  build dirs are ignored and swept;
* restore-time validation: structure mismatch, truncated leaf, missing
  manifest;
* retry() jitter is seedable (RNG02 discipline);
* meta: the ExperimentState fields are statically pinned to the scan
  carries of the fused drivers (new carry state cannot silently escape
  checkpointing).
"""
import os
import random
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save, sweep_tmp
from repro.core import (AsyncConfig, EAConfig, ExperimentState, PoolServer,
                        make_onemax, run_fused, run_fused_async)
from repro.core import island as island_lib
from repro.core import pool as pool_lib
from repro.core.evolution import empty_stats, segment_plan
from repro.core.types import AcceptanceConfig
from repro.runtime import elastic
from repro.runtime.fault import retry

CFG = EAConfig(max_pop=32, min_pop=32, generations_per_epoch=3,
               max_evaluations=10**9)
PROBLEM = make_onemax(24)
KEY = jax.random.key(42)


def leaves(t):
    out = []
    for x in jax.tree.leaves(t):
        if hasattr(x, "dtype") and jax.dtypes.issubdtype(
                x.dtype, jax.dtypes.prng_key):
            x = jax.random.key_data(x)
        out.append(np.asarray(x))
    return out


def trees_equal(a, b):
    return all(np.array_equal(x, y) for x, y in zip(leaves(a), leaves(b)))


def drop_last_snapshot(d):
    """Simulate a kill -9 after the second-to-last snapshot landed."""
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("step_") and not p.endswith(".tmp"))
    assert len(steps) >= 2, steps
    shutil.rmtree(os.path.join(d, f"step_{steps[-1]:08d}"))


class TestSegmentPlan:
    def test_shapes(self):
        assert segment_plan(0, 10, 4) == [4, 4, 2]
        assert segment_plan(4, 10, 4) == [4, 2]
        assert segment_plan(10, 10, 4) == []
        assert segment_plan(0, 10, None) == [10]
        assert segment_plan(0, 10, 0) == [10]
        assert segment_plan(3, 10, None) == [7]

    def test_at_most_two_distinct_lengths(self):
        plan = segment_plan(0, 103, 7)
        assert sum(plan) == 103 and len(set(plan)) <= 2


class TestSegmentedSync:
    def test_segmented_equals_monolithic(self, tmp_path):
        a = run_fused(PROBLEM, CFG, n_islands=4, max_epochs=8, rng=KEY,
                      return_stats=True)
        b = run_fused(PROBLEM, CFG, n_islands=4, max_epochs=8, rng=KEY,
                      return_stats=True, snapshot_every=3,
                      snapshot_dir=str(tmp_path))
        assert trees_equal((a[0], a[1], a[3]), (b[0], b[1], b[3]))
        assert int(a[2]) == int(b[2])

    def test_kill_and_resume_bit_identical(self, tmp_path):
        full = run_fused(PROBLEM, CFG, n_islands=4, max_epochs=8, rng=KEY,
                         return_stats=True, snapshot_every=2,
                         snapshot_dir=str(tmp_path))
        drop_last_snapshot(str(tmp_path))
        res = run_fused(PROBLEM, CFG, n_islands=4, max_epochs=8, rng=KEY,
                        return_stats=True, snapshot_every=2,
                        snapshot_dir=str(tmp_path), resume=True)
        assert trees_equal((full[0], full[1], full[3]),
                           (res[0], res[1], res[3]))
        assert int(full[2]) == int(res[2])

    def test_resume_without_dir_raises(self):
        with pytest.raises(ValueError, match="resume"):
            run_fused(PROBLEM, CFG, n_islands=4, max_epochs=2, resume=True)

    def test_resume_of_finished_run_is_noop_replay(self, tmp_path):
        full = run_fused(PROBLEM, CFG, n_islands=4, max_epochs=6, rng=KEY,
                         snapshot_every=2, snapshot_dir=str(tmp_path))
        again = run_fused(PROBLEM, CFG, n_islands=4, max_epochs=6, rng=KEY,
                          snapshot_every=2, snapshot_dir=str(tmp_path),
                          resume=True)
        assert trees_equal(full[0], again[0])


class TestSegmentedAsync:
    ACFG = AsyncConfig(min_rate=0.5, max_rate=1.0, staleness=2,
                       churn_fraction=0.3, inbox_capacity=3)

    def test_kill_and_resume_with_astate(self, tmp_path):
        full = run_fused_async(PROBLEM, CFG, acfg=self.ACFG, n_islands=4,
                               max_ticks=9, rng=KEY, return_stats=True,
                               return_astate=True, snapshot_every=3,
                               snapshot_dir=str(tmp_path))
        drop_last_snapshot(str(tmp_path))
        res = run_fused_async(PROBLEM, CFG, acfg=self.ACFG, n_islands=4,
                              max_ticks=9, rng=KEY, return_stats=True,
                              return_astate=True, snapshot_every=3,
                              snapshot_dir=str(tmp_path), resume=True)
        # islands, pool, ticks, stats AND the async clocks/inbox/churn state
        assert trees_equal(full, res)

    def test_degenerate_async_segments_match_sync(self, tmp_path):
        sync = run_fused(PROBLEM, CFG, n_islands=4, max_epochs=6, rng=KEY,
                         return_stats=True)
        asyn = run_fused_async(PROBLEM, CFG, acfg=AsyncConfig(), n_islands=4,
                               max_ticks=6, rng=KEY, return_stats=True,
                               snapshot_every=2, snapshot_dir=str(tmp_path))
        assert trees_equal((sync[0], sync[3]), (asyn[0], asyn[3]))


class TestShardedDurability:
    def _mesh(self):
        from jax.sharding import Mesh
        return Mesh(np.array(jax.devices()), ("islands",))

    def test_sharded_kill_and_resume(self, tmp_path):
        from repro.core.sharded import run_fused_sharded
        mesh = self._mesh()
        per = max(1, 4 // mesh.shape["islands"])
        full = run_fused_sharded(mesh, PROBLEM, CFG, islands_per_shard=per,
                                 max_epochs=8, rng=KEY, return_stats=True,
                                 snapshot_every=2, snapshot_dir=str(tmp_path))
        drop_last_snapshot(str(tmp_path))
        res = run_fused_sharded(mesh, PROBLEM, CFG, islands_per_shard=per,
                                max_epochs=8, rng=KEY, return_stats=True,
                                snapshot_every=2, snapshot_dir=str(tmp_path),
                                resume=True)
        assert trees_equal((full[0], full[1], full[3]),
                           (res[0], res[1], res[3]))

    def test_sharded_async_kill_and_resume(self, tmp_path):
        from repro.core.sharded import run_fused_sharded_async
        mesh = self._mesh()
        per = max(1, 4 // mesh.shape["islands"])
        acfg = AsyncConfig(min_rate=0.5, max_rate=1.0, staleness=2,
                           churn_fraction=0.25, inbox_capacity=3)
        # hard enough that no device count solves it before the second
        # snapshot — an early-stop at tick < 8 would leave only step_4
        # and the drop below nothing to resume from (CI runs with
        # --xla_force_host_platform_device_count=8; onemax(24) falls to
        # 8 islands inside 8 ticks)
        hard = make_onemax(96)
        full = run_fused_sharded_async(
            mesh, hard, CFG, acfg=acfg, islands_per_shard=per,
            max_ticks=9, rng=KEY, return_stats=True, return_astate=True,
            snapshot_every=4, snapshot_dir=str(tmp_path))
        drop_last_snapshot(str(tmp_path))
        res = run_fused_sharded_async(
            mesh, hard, CFG, acfg=acfg, islands_per_shard=per,
            max_ticks=9, rng=KEY, return_stats=True, return_astate=True,
            snapshot_every=4, snapshot_dir=str(tmp_path), resume=True)
        assert trees_equal(full, res)


class TestElasticResume:
    # hard enough that 6 epochs never hit the early-stop latch — the
    # resumed run must actually *continue*, not replay a finished state
    HARD = make_onemax(96)

    def test_eight_island_checkpoint_resumes_as_sixteen(self, tmp_path):
        run_fused(self.HARD, CFG, n_islands=8, max_epochs=4, rng=KEY,
                  snapshot_every=2, snapshot_dir=str(tmp_path))
        isl, pool, ep = run_fused(self.HARD, CFG, n_islands=16, max_epochs=6,
                                  rng=KEY, snapshot_dir=str(tmp_path),
                                  resume=True)
        assert isl.pop.shape[0] == 16
        # joiners get fresh identities above the watermark
        assert sorted(np.asarray(isl.uuid).tolist()) == list(range(16))
        assert int(ep) == 6

    def test_shrink_resume(self, tmp_path):
        run_fused(self.HARD, CFG, n_islands=8, max_epochs=4, rng=KEY,
                  snapshot_every=2, snapshot_dir=str(tmp_path))
        isl, _, ep = run_fused(self.HARD, CFG, n_islands=4, max_epochs=6,
                               rng=KEY, snapshot_dir=str(tmp_path),
                               resume=True)
        assert isl.pop.shape[0] == 4
        assert sorted(np.asarray(isl.uuid).tolist()) == [0, 1, 2, 3]
        assert int(ep) == 6


class TestUuidWatermark:
    def _state(self, n):
        islands = island_lib.init_islands(jax.random.key(0), n, PROBLEM, CFG)
        pool = pool_lib.pool_init(16, PROBLEM.genome)
        return ExperimentState(islands=islands, pool=pool, astate=(),
                               key=jax.random.key(1), epoch=jnp.int32(0),
                               stopped=jnp.asarray(False), stats=(),
                               next_uuid=jnp.int32(n))

    def test_shrink_then_grow_never_reuses_uuids(self):
        state = self._state(4)
        state = elastic.resize_experiment(state, 2, PROBLEM, CFG)
        assert sorted(np.asarray(state.islands.uuid).tolist()) == [0, 1]
        state = elastic.resize_experiment(state, 5, PROBLEM, CFG)
        got = sorted(np.asarray(state.islands.uuid).tolist())
        # departed islands 2 and 3 keep their identities forever
        assert got == [0, 1, 4, 5, 6]
        assert int(state.next_uuid) == 7

    def test_grow_islands_default_watermark_is_max_plus_one(self):
        islands = island_lib.init_islands(jax.random.key(0), 2, PROBLEM, CFG)
        grown = elastic.grow_islands(islands, 2, PROBLEM, CFG, None,
                                     jax.random.key(5))
        assert sorted(np.asarray(grown.uuid).tolist()) == [0, 1, 2, 3]

    def test_async_joiners_never_churn(self):
        from repro.core.async_migration import init_async_state
        acfg = AsyncConfig(min_rate=0.5, max_rate=1.0, churn_fraction=1.0)
        astate = init_async_state(jax.random.key(0), 4, acfg, 10,
                                  PROBLEM.genome)
        grown = elastic.grow_async_state(astate, 3)
        assert grown.clock.shape[0] == 7
        assert np.all(np.asarray(grown.down_start[4:]) == elastic.NEVER_CHURN)
        assert np.all(np.asarray(grown.inbox_fitness[4:]) == pool_lib.NEG_INF)
        # rate scale is preserved (batch mean), clocks/fires start at zero
        assert np.all(np.asarray(grown.fires[4:]) == 0)


class TestPoolServerWAL:
    def _fill(self, server, n, length=6):
        for i in range(n):
            server.put(np.full(length, i % 120, np.int8), float(i), uuid=i)

    def test_rehydrate_entries_seq_and_stats(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        s = PoolServer(capacity=4, journal_path=jp)
        self._fill(s, 7)
        s.close()
        s2 = PoolServer(capacity=4, journal_path=jp, resume=True)
        st = s2.stats()
        assert st["size"] == 4 and st["puts"] == 7 and st["best_fitness"] == 6.0
        assert sorted(e.seq for e in s2._entries) == [3, 4, 5, 6]
        assert s2._seq == 7
        g, f = s2.get_best()
        assert f == 6.0 and g.dtype == np.int8

    def test_exactly_once_across_restart(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        s = PoolServer(capacity=8, journal_path=jp)
        self._fill(s, 6)
        got1, cur1, drop1 = s.get_since(-1, cursor_id="bridge")
        assert [e.seq for e in got1] == [0, 1, 2, 3, 4, 5] and drop1 == 0
        s.close()
        s2 = PoolServer(capacity=8, journal_path=jp, resume=True)
        self._fill(s2, 3)           # seqs 6, 7, 8
        # consumer lost its own cursor: seq=-1 + the stored server cursor
        got2, cur2, drop2 = s2.get_since(-1, cursor_id="bridge")
        assert [e.seq for e in got2] == [6, 7, 8]
        assert not set(e.seq for e in got1) & set(e.seq for e in got2)

    def test_dropped_accounting_survives_restart(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        s = PoolServer(capacity=4, journal_path=jp)
        self._fill(s, 10)            # seqs 0..9; 0..5 ring-evicted
        s.close()
        s2 = PoolServer(capacity=4, journal_path=jp, resume=True)
        got, cur, dropped = s2.get_since(-1, cursor_id="c")
        assert [e.seq for e in got] == [6, 7, 8, 9]
        assert dropped == 6 and cur == 9

    def test_torn_final_line_tolerated(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        s = PoolServer(capacity=4, journal_path=jp)
        self._fill(s, 5)
        s.close()
        with open(jp, "a") as f:
            f.write('{"op": "put", "uuid": 3, "fit')     # kill -9 mid-write
        s2 = PoolServer(capacity=4, journal_path=jp, resume=True)
        assert s2.stats()["puts"] == 5 and s2._seq == 5
        # and the journal keeps appending cleanly after the torn tail
        s2.put(np.zeros(6, np.int8), 99.0)
        s2.close()
        s3 = PoolServer(capacity=4, journal_path=jp, resume=True)
        assert s3.stats()["best_fitness"] == 99.0

    def test_unterminated_final_record_is_healed(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        s = PoolServer(capacity=4, journal_path=jp)
        self._fill(s, 3)
        s.close()
        with open(jp, "rb") as f:
            data = f.read()
        with open(jp, "wb") as f:       # kill landed between data and \n
            f.write(data.rstrip(b"\n"))
        s2 = PoolServer(capacity=4, journal_path=jp, resume=True)
        assert s2.stats()["puts"] == 3  # the record itself is complete
        s2.put(np.zeros(6, np.int8), 7.0)
        s2.close()
        s3 = PoolServer(capacity=4, journal_path=jp, resume=True)
        assert s3.stats()["puts"] == 4 and s3.stats()["best_fitness"] == 7.0

    def test_replay_reproduces_acceptance_decisions(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        acc = AcceptanceConfig(policy="elitist")
        s = PoolServer(capacity=3, journal_path=jp, acceptance=acc)
        for f in (5.0, 1.0, 3.0, 2.0, 4.0):
            s.put(np.full(4, int(f), np.int8), f)
        fits = sorted(e.fitness for e in s._entries)
        s.close()
        # replay does NOT re-run the policy — it applies journaled slots
        s2 = PoolServer(capacity=3, journal_path=jp, acceptance=acc,
                        resume=True)
        assert sorted(e.fitness for e in s2._entries) == fits
        assert s2.stats()["rejected"] == s.stats()["rejected"]

    def test_reset_replay(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        s = PoolServer(capacity=4, journal_path=jp)
        self._fill(s, 3)
        s.reset()
        s.put(np.ones(6, np.int8), 42.0)
        s.close()
        s2 = PoolServer(capacity=4, journal_path=jp, resume=True)
        assert s2.stats()["experiment"] == 1
        assert s2.stats()["size"] == 1 and s2.stats()["best_fitness"] == 42.0

    def test_bridge_cursor_survives_bridge_restart(self, tmp_path):
        from repro.core.async_migration import AsyncHostBridge
        jp = str(tmp_path / "journal.jsonl")
        server = PoolServer(capacity=16, journal_path=jp)
        for i in range(5):
            server.put(np.full(24, 1, np.int8), float(i), uuid=7)
        pool = pool_lib.pool_init(8, PROBLEM.genome)
        b1 = AsyncHostBridge(server, pull=64, cursor_id="pod")
        pool = b1.flush(b1.sync(pool))
        assert b1.pulled == 5
        # the bridge dies and comes back with no local position; the
        # server-side named cursor prevents any re-delivery
        b2 = AsyncHostBridge(server, pull=64, cursor_id="pod")
        pool = b2.flush(b2.sync(pool))
        assert b2.pulled == 0 and b2.dropped == 0

    def test_no_resume_keeps_legacy_append_behaviour(self, tmp_path):
        jp = str(tmp_path / "journal.jsonl")
        s = PoolServer(capacity=4, journal_path=jp)
        self._fill(s, 3)
        s.close()
        s2 = PoolServer(capacity=4, journal_path=jp)   # resume not requested
        assert s2.stats()["size"] == 0 and s2._seq == 0


class TestCheckpointerRegressions:
    def test_wait_drains_errors(self, tmp_path):
        blocker = tmp_path / "dir_is_a_file"
        blocker.write_text("not a directory")
        ck = Checkpointer(str(blocker / "sub"))
        ck.save_async(1, {"x": jnp.zeros(2)})
        with pytest.raises(OSError):
            ck.wait()
        # the stale error must not re-raise forever
        ck.wait()
        ck.directory = str(tmp_path / "ok")
        ck.save_async(2, {"x": jnp.zeros(2)})
        ck.wait()
        assert latest_step(ck.directory) == 2

    def test_save_async_prunes_finished_threads(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save_async(1, {"x": jnp.zeros(2)})
        ck.wait()
        deadline = time.time() + 5
        while any(t.is_alive() for t in ck._pending) and time.time() < deadline:
            time.sleep(0.01)
        ck.save_async(2, {"x": jnp.zeros(2)})
        assert len(ck._pending) == 1       # finished writers were pruned
        ck.wait()

    def test_stale_tmp_swept_on_init_and_ignored_by_latest(self, tmp_path):
        save(str(tmp_path), 3, {"x": jnp.zeros(2)})
        stale = tmp_path / "step_00000007.tmp"
        stale.mkdir()
        (stale / "leaf_00000.npy").write_bytes(b"partial")
        assert latest_step(str(tmp_path)) == 3   # .tmp is never a candidate
        Checkpointer(str(tmp_path))
        assert not stale.exists()                # swept at process start
        assert latest_step(str(tmp_path)) == 3

    def test_sweep_tmp_reports_removals(self, tmp_path):
        (tmp_path / "step_00000001.tmp").mkdir()
        (tmp_path / "step_00000002").mkdir()
        removed = sweep_tmp(str(tmp_path))
        assert len(removed) == 1 and removed[0].endswith(".tmp")
        assert (tmp_path / "step_00000002").exists()


class TestRestoreValidation:
    def test_structure_mismatch(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.zeros(2)})
        with pytest.raises(ValueError, match="mismatch"):
            restore(str(tmp_path), target={"b": jnp.zeros(2)})

    def test_truncated_leaf_detected(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.arange(64.0)})
        step = tmp_path / "step_00000001"
        leaf = next(p for p in os.listdir(step) if p.startswith("leaf_"))
        data = (step / leaf).read_bytes()
        (step / leaf).write_bytes(data[: len(data) // 2])
        with pytest.raises(Exception):
            restore(str(tmp_path), target={"a": jnp.zeros(64)})

    def test_missing_manifest_dir_is_not_a_candidate(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.zeros(2)})
        bad = tmp_path / "step_00000009"
        bad.mkdir()                      # a dir with no manifest.json
        assert latest_step(str(tmp_path)) == 1
        got = restore(str(tmp_path), target={"a": jnp.zeros(2)})
        assert np.asarray(got["a"]).shape == (2,)

    def test_restore_ignores_target_leaf_shapes(self, tmp_path):
        # the property elastic resume relies on: structure-only matching
        save(str(tmp_path), 1, {"a": jnp.zeros((8, 3))})
        got = restore(str(tmp_path), target={"a": jnp.zeros((16, 3))})
        assert np.asarray(got["a"]).shape == (8, 3)


class TestRetryJitter:
    def _delays(self, rng):
        seen = []
        calls = {"n": 0}

        def boom():
            calls["n"] += 1
            raise RuntimeError("nope")

        with pytest.raises(RuntimeError):
            retry(boom, retries=3, base_delay=0.01, sleep=seen.append,
                  rng=rng)
        assert calls["n"] == 4
        return seen

    def test_seeded_rng_is_deterministic(self):
        a = self._delays(random.Random(7))
        b = self._delays(random.Random(7))
        assert a == b and len(a) == 3

    def test_does_not_touch_global_random(self):
        random.seed(123)
        state = random.getstate()
        self._delays(random.Random(1))
        self._delays(None)   # rng=None draws from the module-private stream
        assert random.getstate() == state


class TestSnapshotCoverageMeta:
    """Static pin: every fused-driver scan-carry element has an
    ExperimentState home (the snapshot is sufficient by construction)."""

    def _project(self):
        from repro.analysis.engine import collect_python_files
        from repro.analysis.symbols import load_project
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        return load_project(collect_python_files(
            [os.path.join(root, "src", "repro", "core")], root=root))

    def test_carries_are_covered(self):
        from repro.analysis import snapshot
        assert snapshot.check_coverage(self._project()) == []

    def test_extraction_matches_runtime(self):
        from repro.analysis import snapshot
        carries = snapshot.scan_carry_names(self._project())
        assert carries["repro.core.evolution.fused_scan"] == \
            ["islands", "pool", "key", "epoch", "stopped", "obs"]
        assert carries["repro.core.async_migration.fused_scan_async"] == \
            ["islands", "pool", "astate", "key", "tick", "stopped", "obs"]
        fields = snapshot.experiment_state_fields(self._project())
        assert fields == list(ExperimentState._fields)

    def test_coverage_check_catches_an_escaped_carry(self):
        # break the tick->epoch alias: the async carry element 'tick' then
        # has no ExperimentState home and must be reported
        from repro.analysis import snapshot
        project = self._project()
        old = snapshot.CARRY_ALIASES
        try:
            snapshot.CARRY_ALIASES = {}
            problems = snapshot.check_coverage(project)
            assert any("tick" in p and "escape" in p for p in problems)
        finally:
            snapshot.CARRY_ALIASES = old


class TestEmptyStatsTemplate:
    def test_dtypes_match_collect_stats(self):
        from repro.core.evolution import collect_stats
        islands = island_lib.init_islands(jax.random.key(0), 2, PROBLEM, CFG)
        live = jax.tree.map(np.asarray, collect_stats(islands, 1))
        tmpl = empty_stats()
        for a, b in zip(jax.tree.leaves(tmpl), jax.tree.leaves(live)):
            assert a.dtype == np.asarray(b).dtype
