"""End-to-end behaviour tests for the full system.

These are the paper's claims, executed against the public API:
  * a pooled multi-island experiment solves the paper's trap problem,
  * migration measurably helps over isolated islands,
  * the LM training driver reduces loss and survives restart,
  * the serving driver decodes tokens,
  * the PBT bridge (paper's technique -> LM training) improves val loss.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EAConfig, MigrationConfig, make_trap,
                        run_experiment)


class TestEvolutionSystem:
    def test_quickstart_trap40_solves(self):
        """The paper's 40-trap, 8 pooled W²-style islands, eval budget 5M."""
        problem = make_trap(n_traps=40, l=4, a=1.0, b=2.0, z=3.0)
        cfg = EAConfig(max_pop=256, min_pop=128, generations_per_epoch=100,
                       mutation_rate=1.0 / 160)
        res = run_experiment(problem, cfg, MigrationConfig(pool_capacity=64),
                             n_islands=8, max_epochs=40,
                             rng=jax.random.key(0))
        assert res.success, f"best={float(res.islands.best_fitness.max())}"
        assert res.evaluations_to_solution < 5_000_000

    def test_migration_helps(self):
        """Pool migration reaches the optimum in no more epochs than
        isolated islands on a deceptive problem (averaged over seeds)."""
        problem = make_trap(n_traps=16, l=4)
        cfg = EAConfig(max_pop=128, min_pop=64, generations_per_epoch=50,
                       mutation_rate=1.0 / 64)

        def epochs_needed(server_up, seed):
            res = run_experiment(problem, cfg, MigrationConfig(),
                                 n_islands=6, max_epochs=30,
                                 server_up=server_up,
                                 rng=jax.random.key(seed))
            return res.epochs if res.success else 31

        pooled = [epochs_needed(None, s) for s in range(3)]
        isolated = [epochs_needed(lambda e: False, s) for s in range(3)]
        assert np.mean(pooled) <= np.mean(isolated) + 0.5, \
            (pooled, isolated)


class TestTrainingSystem:
    def test_train_reduces_loss_and_resumes(self):
        from repro.launch.train import train
        with tempfile.TemporaryDirectory() as ckpt:
            state, losses = train("minicpm-2b", smoke=True, steps=30,
                                  batch=8, seq=64, lr=3e-3, ckpt_dir=ckpt,
                                  ckpt_every=15, verbose=False)
            assert losses[-1] < losses[0]
            # resume continues from checkpointed data step
            state2, losses2 = train("minicpm-2b", smoke=True, steps=40,
                                    batch=8, seq=64, lr=3e-3, ckpt_dir=ckpt,
                                    resume=True, verbose=False)
            assert len(losses2) == 10   # only steps 30..40 re-run
            assert all(np.isfinite(losses2))

    def test_serve_decodes(self):
        from repro.launch.serve import serve
        toks = serve("yi-9b", smoke=True, batch=2, prompt_len=16,
                     new_tokens=6, verbose=False)
        assert toks.shape == (2, 6)
        assert int(toks.max()) < 256

    def test_pbt_bridge_improves(self):
        from repro.launch.evolve import run_pbt
        ctrl = run_pbt(arch="minicpm-2b", members=3, epochs=4,
                       steps_per_epoch=8, batch=4, seq=32, verbose=False)
        hist = ctrl.history
        first = np.mean([h["val_loss"] for h in hist[:3]])
        last = np.mean([h["val_loss"] for h in hist[-3:]])
        assert last <= first + 0.05
        assert ctrl.pool.stats()["puts"] >= 12
