"""Unified observability layer (ISSUE 10).

* :class:`repro.obs.counters.ObsCounters` ride the fused scan carries as
  pure integer accumulation, so harvested totals are bit-for-bit
  invariant to segmentation (sync, async and sharded drivers), identical
  across generation-kernel impls under ``acceptance="always"`` with
  ``inbox_capacity=1`` (availability-driven masks, never fitness-driven),
  and the ledger ``delivered == accepted + rejected`` balances by
  construction — including under churn and rejecting policies;
* :class:`repro.obs.trace.Tracer` records spans thread-safely into a
  bounded ring; the Chrome trace-event export is pinned by a golden
  fixture (``tests/data/golden_trace.json``) built on an injectable
  deterministic clock.  Regenerate deliberately after an export-format
  change with:

      PYTHONPATH=src python tests/test_obs.py --regen

* :mod:`repro.obs.metrics` round-trips the log-binned latency histogram
  through the Prometheus text exposition;
* the ``python -m repro.obs`` timeline CLI merges traces + harvests into
  one summary and exits nonzero on an unbalanced ledger.
"""
import itertools
import json
import os
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from repro.core import (AsyncConfig, EAConfig, MigrationConfig, make_onemax,
                        make_rastrigin, run_fused, run_fused_async)
from repro.core.types import AcceptanceConfig
from repro.obs import __main__ as obs_cli
from repro.obs import counters as obs_counters
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.trace import Tracer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "data", "golden_trace.json")

CFG = EAConfig(max_pop=32, min_pop=32, generations_per_epoch=3,
               max_evaluations=10**9)
PROBLEM = make_onemax(24)
# never solved at this budget: no early-stop latch, so fired counts can't
# diverge between impls/runs that would otherwise stop at different epochs
HARD = make_rastrigin(dim=16)
KEY = jax.random.key(42)
ACFG = AsyncConfig(min_rate=0.5, max_rate=1.0, staleness=2,
                   churn_fraction=0.3, inbox_capacity=3)


@pytest.fixture(autouse=True)
def _module_tracer_off():
    """Tests that enable() the module tracer must not leak it."""
    yield
    obs_trace.disable()


# ---------------------------------------------------------------------------
# on-device counters: ledger + segmentation/impl invariance
# ---------------------------------------------------------------------------
def balanced(harvest):
    t = harvest["totals"]
    return t["delivered"] == t["accepted"] + t["rejected"]


class TestCountersSync:
    def test_harvest_shape_and_ledger(self):
        *_, obs = run_fused(PROBLEM, CFG, n_islands=6, max_epochs=8, rng=KEY,
                            return_obs=True)
        assert obs["n_islands"] == 6
        assert len(obs["fired"]) == 6
        assert np.asarray(obs["inbox_age_hist"]).shape == (
            6, obs_counters.AGE_BINS)
        assert obs["totals"]["fired"] > 0
        assert balanced(obs)
        # the sync driver never churns and absorbs at delivery (age 0)
        assert obs["totals"]["churn_down"] == 0
        ages = obs["totals"]["inbox_age_hist"]
        assert sum(ages[1:]) == 0 and ages[0] == obs["totals"]["accepted"]

    def test_early_stop_latch(self):
        easy = make_onemax(8)
        *_, obs = run_fused(easy, CFG, n_islands=4, max_epochs=30,
                            rng=jax.random.key(1), return_obs=True)
        assert 1 <= obs["early_stop_epoch"] <= 30

    def test_segmented_matches_monolithic(self, tmp_path):
        mono = run_fused(PROBLEM, CFG, n_islands=6, max_epochs=9, rng=KEY,
                         return_obs=True)[-1]
        seg = run_fused(PROBLEM, CFG, n_islands=6, max_epochs=9, rng=KEY,
                        return_obs=True, snapshot_every=3,
                        snapshot_dir=str(tmp_path))[-1]
        assert seg == mono

    def test_elitist_policy_rejects_and_balances(self):
        mig = MigrationConfig(acceptance=AcceptanceConfig(policy="elitist"))
        *_, obs = run_fused(HARD, CFG, mig, n_islands=6, max_epochs=10,
                            rng=KEY, return_obs=True)
        assert obs["totals"]["rejected"] > 0
        assert obs["totals"]["accepted"] < obs["totals"]["delivered"]
        assert balanced(obs)


class TestCountersAsync:
    def test_churn_is_counted_and_ledger_balances(self):
        churny = AsyncConfig(min_rate=0.4, max_rate=1.0, staleness=2,
                             churn_fraction=0.5, inbox_capacity=3)
        # HARD never early-stops, so the run reaches the churn windows
        # (which open inside [0.25, 0.75) x max_ticks)
        *_, obs = run_fused_async(HARD, CFG, acfg=churny, n_islands=6,
                                  max_ticks=12, rng=KEY, return_obs=True)
        assert obs["totals"]["churn_down"] > 0
        assert balanced(obs)
        # absorb-time re-gate is not double-counted: every absorbed
        # immigrant passed the delivery gate first
        assert sum(obs["totals"]["inbox_age_hist"]) <= obs["totals"]["accepted"]

    def test_segmented_matches_monolithic(self, tmp_path):
        mono = run_fused_async(PROBLEM, CFG, acfg=ACFG, n_islands=6,
                               max_ticks=9, rng=KEY, return_obs=True)[-1]
        seg = run_fused_async(PROBLEM, CFG, acfg=ACFG, n_islands=6,
                              max_ticks=9, rng=KEY, return_obs=True,
                              snapshot_every=3, snapshot_dir=str(tmp_path))[-1]
        assert seg == mono

    def test_degenerate_async_matches_sync(self):
        sync = run_fused(PROBLEM, CFG, n_islands=6, max_epochs=8, rng=KEY,
                         return_obs=True)[-1]
        asyn = run_fused_async(PROBLEM, CFG, acfg=AsyncConfig(), n_islands=6,
                               max_ticks=8, rng=KEY, return_obs=True)[-1]
        assert asyn == sync

    @pytest.mark.parametrize("impl", ["jnp", "pallas", "pallas_tiled"])
    def test_impl_invariant_totals(self, impl):
        """acceptance='always' + inbox_capacity=1: every mask the counters
        accumulate is availability/clock-driven, so totals are identical
        across generation impls even though fitness trajectories differ.
        (capacity>1 + staleness makes the absorbed *pick* fitness-dependent,
        which is why the invariance contract pins capacity=1.)"""
        cfg = EAConfig(max_pop=32, min_pop=32, generations_per_epoch=3,
                       max_evaluations=10**9, impl=impl)
        acfg = AsyncConfig(min_rate=0.5, max_rate=1.0, staleness=2,
                           churn_fraction=0.3, inbox_capacity=1)
        *_, obs = run_fused_async(HARD, cfg, acfg=acfg, n_islands=6,
                                  max_ticks=8, rng=KEY, return_obs=True)
        ref = run_fused_async(
            HARD, EAConfig(max_pop=32, min_pop=32, generations_per_epoch=3,
                           max_evaluations=10**9),
            acfg=acfg, n_islands=6, max_ticks=8, rng=KEY, return_obs=True)[-1]
        assert obs == ref


class TestCountersSharded:
    def _mesh(self):
        from jax.sharding import Mesh
        return Mesh(np.array(jax.devices()), ("islands",))

    def test_sharded_segmented_matches_monolithic(self, tmp_path):
        from repro.core.sharded import run_fused_sharded
        mesh = self._mesh()
        per = max(1, 8 // mesh.shape["islands"])
        mono = run_fused_sharded(mesh, PROBLEM, CFG, islands_per_shard=per,
                                 max_epochs=8, rng=KEY, return_obs=True)[-1]
        seg = run_fused_sharded(mesh, PROBLEM, CFG, islands_per_shard=per,
                                max_epochs=8, rng=KEY, return_obs=True,
                                snapshot_every=3,
                                snapshot_dir=str(tmp_path))[-1]
        assert seg == mono
        assert balanced(mono)

    def test_sharded_async_segmented_matches_monolithic(self, tmp_path):
        from repro.core.sharded import run_fused_sharded_async
        mesh = self._mesh()
        per = max(1, 8 // mesh.shape["islands"])
        mono = run_fused_sharded_async(
            mesh, HARD, CFG, acfg=ACFG, islands_per_shard=per, max_ticks=9,
            rng=KEY, return_obs=True)[-1]
        seg = run_fused_sharded_async(
            mesh, HARD, CFG, acfg=ACFG, islands_per_shard=per, max_ticks=9,
            rng=KEY, return_obs=True, snapshot_every=4,
            snapshot_dir=str(tmp_path))[-1]
        assert seg == mono
        assert balanced(mono)


# ---------------------------------------------------------------------------
# host tracer
# ---------------------------------------------------------------------------
def _golden_trace():
    """Deterministic trace: counter clock (1ms per reading), main thread."""
    ticks = itertools.count()
    tracer = Tracer(clock=lambda: next(ticks) * 1e-3)
    with tracer.span("driver.segment", segment=0):
        with tracer.span("driver.tick", tick=0):
            pass
        with tracer.span("driver.tick", tick=1):
            pass
    with tracer.span("checkpoint.snapshot", step=2):
        with tracer.span("checkpoint.write"):
            pass
    tracer.instant("server.down")
    return tracer.to_chrome()


class TestTracer:
    def test_span_records_complete_event(self):
        tracer = Tracer()
        with tracer.span("pool.put", n=3):
            pass
        (ev,) = tracer.events()
        assert ev["ph"] == "X" and ev["name"] == "pool.put"
        assert ev["dur"] >= 0 and ev["args"] == {"n": 3}
        assert ev["pid"] == 1 and ev["tid"] == 1

    def test_ring_keeps_the_tail(self):
        tracer = Tracer(maxlen=16)
        for i in range(100):
            with tracer.span("s", i=i):
                pass
        evs = tracer.events()
        assert len(evs) == 16
        assert [e["args"]["i"] for e in evs] == list(range(84, 100))

    def test_thread_safety_under_concurrent_spans(self):
        tracer = Tracer()
        n_threads, n_spans = 8, 200
        start = threading.Barrier(n_threads)

        def worker(k):
            start.wait()
            for i in range(n_spans):
                with tracer.span("worker.op", k=k, i=i):
                    pass

        threads = [threading.Thread(target=worker, args=(k,), name=f"w{k}")
                   for k in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        evs = tracer.events()
        assert len(evs) == n_threads * n_spans
        # stable small-int tids, one per recording thread, names captured
        assert {e["tid"] for e in evs} == set(range(1, n_threads + 1))
        chrome = tracer.to_chrome()
        names = {ev["args"]["name"] for ev in chrome["traceEvents"]
                 if ev["ph"] == "M"}
        assert names == {f"w{k}" for k in range(n_threads)}
        # per-thread event order is preserved in the ring
        for k in range(n_threads):
            mine = [e["args"]["i"] for e in evs if e["args"]["k"] == k]
            assert mine == list(range(n_spans))

    def test_module_level_span_is_noop_when_disabled(self):
        obs_trace.disable()
        assert obs_trace.span("x") is obs_trace.span("y")
        tracer = obs_trace.enable()
        with obs_trace.span("pool.get_random"):
            pass
        obs_trace.instant("mark")
        assert [e["name"] for e in tracer.events()] == ["pool.get_random",
                                                        "mark"]
        obs_trace.disable()
        obs_trace.instant("dropped")
        assert len(tracer.events()) == 2

    def test_golden_chrome_trace(self):
        assert os.path.isfile(GOLDEN_PATH), (
            f"missing {GOLDEN_PATH} — regenerate with "
            f"`PYTHONPATH=src python tests/test_obs.py --regen`")
        with open(GOLDEN_PATH) as fh:
            golden = json.load(fh)
        live = _golden_trace()
        assert live == golden, (
            "Chrome trace export drifted from tests/data/golden_trace.json "
            "— if the format change is deliberate, regenerate with "
            "`PYTHONPATH=src python tests/test_obs.py --regen`")
        # and the fixture itself is a valid Chrome trace object
        assert golden["displayTimeUnit"] == "ms"
        xs = [e for e in golden["traceEvents"] if e["ph"] == "X"]
        assert all({"name", "ts", "dur", "pid", "tid"} <= set(e) for e in xs)


# ---------------------------------------------------------------------------
# metrics: histogram + Prometheus text round-trip
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_hist_index_value_consistent(self):
        for ms in (0.01, 0.05, 1.0, 15.0, 1000.0, 500_000.0):
            i = obs_metrics.hist_index(ms)
            assert 0 <= i < obs_metrics.HIST_BINS
            assert obs_metrics.hist_value(i) <= obs_metrics.hist_upper(i)

    def test_percentiles(self):
        h = obs_metrics.hist_new()
        for ms in [1.0] * 98 + [1000.0] * 2:
            h[obs_metrics.hist_index(ms)] += 1
        assert obs_metrics.hist_percentile(h, 0.50) == pytest.approx(1.0,
                                                                     rel=0.1)
        assert obs_metrics.hist_percentile(h, 0.99) == pytest.approx(1000.0,
                                                                     rel=0.1)

    def test_prometheus_round_trip(self):
        h = obs_metrics.hist_new()
        samples = [0.2, 1.5, 1.5, 80.0, 2500.0]
        for ms in samples:
            h[obs_metrics.hist_index(ms)] += 1
        text = obs_metrics.render_prometheus(
            counters={"requests": 17}, gauges={"queue_depth": 3.5},
            histograms={"verb_put_latency": (h, sum(samples))})
        parsed = obs_metrics.parse_prometheus(text)
        assert parsed["repro_requests"] == 17
        assert parsed["repro_queue_depth"] == 3.5
        assert parsed['repro_verb_put_latency_seconds_bucket{le="+Inf"}'] \
            == len(samples)
        assert parsed["repro_verb_put_latency_seconds_count"] == len(samples)
        assert parsed["repro_verb_put_latency_seconds_sum"] == pytest.approx(
            sum(samples) / 1e3)
        # cumulative buckets are monotone and end at the total count
        buckets = [v for k, v in parsed.items() if "_bucket{" in k]
        assert buckets == sorted(buckets)
        assert buckets[-1] == len(samples)

    def test_prometheus_type_lines(self):
        text = obs_metrics.render_prometheus(counters={"a": 1},
                                             gauges={"b": 2})
        assert "# TYPE repro_a counter" in text
        assert "# TYPE repro_b gauge" in text


# ---------------------------------------------------------------------------
# timeline CLI
# ---------------------------------------------------------------------------
def _fake_harvest(fired=10, delivered=8, accepted=6, rejected=2,
                  churn=3, n=2):
    return {"n_islands": n, "fired": [fired // n] * n,
            "delivered": [delivered // n] * n,
            "accepted": [accepted // n] * n,
            "rejected": [rejected // n] * n, "churn_down": [churn // n] * n,
            "inbox_age_hist": [[0] * obs_counters.AGE_BINS] * n,
            "early_stop_epoch": -1,
            "totals": {"fired": fired, "delivered": delivered,
                       "accepted": accepted, "rejected": rejected,
                       "churn_down": churn,
                       "inbox_age_hist": [0] * obs_counters.AGE_BINS}}


class TestTimelineCLI:
    def test_span_summary(self):
        events = _golden_trace()["traceEvents"]
        spans = obs_cli.span_summary(events)
        assert spans["driver.tick"]["count"] == 2
        assert spans["driver.segment"]["count"] == 1
        assert spans["checkpoint.write"]["count"] == 1
        assert spans["driver.segment"]["total_ms"] \
            >= spans["driver.tick"]["total_ms"]
        assert spans["driver.tick"]["p50_ms"] <= spans["driver.tick"]["p99_ms"]

    def test_ledger_rates(self):
        rates = obs_cli.ledger_rates(_fake_harvest(), n_ticks=10)
        assert rates["ledger_balanced"]
        assert rates["delivery_rate"] == pytest.approx(0.8)
        assert rates["rejection_rate"] == pytest.approx(0.25)
        assert rates["churn_occupancy"] == pytest.approx(3 / 20)
        broken = obs_cli.ledger_rates(_fake_harvest(rejected=1))
        assert not broken["ledger_balanced"]

    def test_merge_traces_repids(self, tmp_path):
        for i in range(2):
            with open(tmp_path / f"t{i}.json", "w") as fh:
                json.dump(_golden_trace(), fh)
        merged = obs_cli.merge_traces([str(tmp_path / "t0.json"),
                                       str(tmp_path / "t1.json")])
        assert {e["pid"] for e in merged} == {1, 2}

    def _write_inputs(self, tmp_path, harvest):
        trace = tmp_path / "trace.json"
        obsj = tmp_path / "obs.json"
        with open(trace, "w") as fh:
            json.dump(_golden_trace(), fh)
        with open(obsj, "w") as fh:
            json.dump(harvest, fh)
        return str(trace), str(obsj)

    def test_cli_end_to_end_and_stamp(self, tmp_path):
        trace, obsj = self._write_inputs(tmp_path, _fake_harvest())
        bench = tmp_path / "BENCH.json"
        with open(bench, "w") as fh:
            json.dump({"rows": []}, fh)
        out = tmp_path / "summary.json"
        rc = obs_cli.main([trace, "--obs", obsj, "--json", str(out),
                           "--stamp", str(bench)])
        assert rc == 0
        with open(out) as fh:
            summary = json.load(fh)
        assert summary["counters"]["ledger_balanced"]
        assert summary["events"] == 6   # 5 spans + 1 instant marker
        with open(bench) as fh:
            stamped = json.load(fh)
        assert stamped["obs_timeline"]["spans"]["driver.tick"]["count"] == 2

    def test_cli_fails_on_unbalanced_ledger(self, tmp_path):
        trace, obsj = self._write_inputs(tmp_path,
                                         _fake_harvest(accepted=9))
        assert obs_cli.main([trace, "--obs", obsj]) == 1

    def test_cli_is_jax_free(self):
        """The timeline tool must import on the jax-free server tier."""
        code = ("import sys, repro.obs.__main__, repro.obs.metrics, "
                "repro.obs.trace; "
                "assert 'jax' not in sys.modules, 'obs CLI pulled in jax'")
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src") + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=120)
        assert proc.returncode == 0, proc.stderr[-2000:]


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as fh:
            json.dump(_golden_trace(), fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    else:
        sys.exit(pytest.main([__file__, "-q"]))
