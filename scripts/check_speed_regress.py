#!/usr/bin/env python
"""Speed-regression gate: fail CI if the fresh speed smoke lost >30%
evals/sec against the committed BENCH_speed.json on the same backend.

Noise-aware: both sides compare on ``evals_per_sec_median`` (the smoke
runs 3 seeded repeats; a median shrugs off one stolen timeslice on the
shared 1-core CI box, where a single-run mean flapped the gate), falling
back to ``evals_per_sec`` for baselines written before the median field
existed. Each row's coefficient of variation is printed so a noisy
comparison is visible in the CI log even when it passes.

Rows are matched on (problem, genome_length, impl, max_pop, islands,
generations_per_epoch) and only compared when the committed baseline was
measured on the same jax backend AND the same pallas_interpret setting
(interpret-mode emulation numbers and TPU numbers are different universes
— comparing across them would gate on hardware, not on code). Unmatched
rows are reported but never fail the gate, so adding scenarios or a new
backend doesn't require regenerating every baseline first.

Usage:
    python scripts/check_speed_regress.py \
        --baseline BENCH_speed.json --fresh /tmp/fresh_speed.json \
        [--threshold 0.30]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Tuple


def _key(row: Dict[str, Any]) -> Tuple:
    return (row["problem"], row["genome_length"], row["impl"],
            row.get("max_pop"), row.get("islands"),
            row.get("generations_per_epoch"))


def _env(payload: Dict[str, Any]) -> Tuple:
    host = payload.get("host", {})
    env = host.get("env", {})
    return (host.get("backend"), env.get("pallas_interpret"))


def _eps(row: Dict[str, Any]) -> float:
    """The gated throughput: median over repeats when recorded."""
    return row.get("evals_per_sec_median", row["evals_per_sec"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default="BENCH_speed.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional evals/sec drop (0.30 = "
                         "fail below 70%% of baseline)")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        base = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    if _env(base) != _env(fresh):
        print(f"speed gate: SKIP — baseline env {_env(base)} != fresh env "
              f"{_env(fresh)} (cross-backend numbers are not comparable)")
        return 0

    base_rows = {_key(r): r for r in base.get("rows", [])}
    failures, compared = [], 0
    for row in fresh.get("rows", []):
        ref = base_rows.get(_key(row))
        if ref is None:
            print(f"speed gate: new row (no baseline): {_key(row)}")
            continue
        compared += 1
        floor = _eps(ref) * (1.0 - args.threshold)
        status = "OK" if _eps(row) >= floor else "REGRESSED"
        cv = row.get("evals_per_sec_cv")
        noise = f" cv={cv:.1%}" if cv is not None else ""
        print(f"speed gate: {row['problem']:>14s} L={row['genome_length']:<5d}"
              f" {row['impl']:>12s}: {_eps(row):>12.0f} vs "
              f"baseline {_eps(ref):>12.0f} "
              f"(floor {floor:>12.0f}){noise} {status}")
        if status == "REGRESSED":
            failures.append(_key(row))

    if not compared:
        print("speed gate: SKIP — no comparable rows")
        return 0
    if failures:
        print(f"speed gate: FAIL — {len(failures)} row(s) regressed "
              f">{args.threshold:.0%} evals/sec: {failures}")
        return 1
    print(f"speed gate: OK — {compared} row(s) within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
