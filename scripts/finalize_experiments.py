"""Inject generated tables into EXPERIMENTS.md from bench_output.txt and
the dry-run artifacts. Idempotent (placeholders survive as anchors)."""
import re
import sys

sys.path.insert(0, "src")


def block_from_bench(bench_text: str, header: str) -> str:
    """Extract the CSV lines under a '== ... ==' header."""
    lines = bench_text.splitlines()
    out, active = [], False
    for ln in lines:
        if ln.startswith("== "):
            active = header in ln
            continue
        if active:
            if not ln.strip():
                break
            out.append(ln)
    return "\n".join(out)


def csv_to_md(csv_text: str) -> str:
    rows = [r for r in csv_text.splitlines() if r.strip()]
    if not rows:
        return "_(run `python -m benchmarks.run` to populate)_"
    cells = [r.split(",") for r in rows]
    width = max(len(c) for c in cells)
    cells = [c + [""] * (width - len(c)) for c in cells]
    md = ["| " + " | ".join(cells[0]) + " |",
          "|" + "---|" * width]
    md += ["| " + " | ".join(c) + " |" for c in cells[1:]]
    return "\n".join(md)


def main():
    try:
        bench = open("bench_output.txt").read()
    except FileNotFoundError:
        bench = ""
    from benchmarks import roofline
    roof = "\n".join(roofline.table("16x16"))

    doc = open("EXPERIMENTS.md").read()

    def put(anchor: str, content: str) -> None:
        nonlocal doc
        pat = re.compile(f"<!--{anchor}-->.*?(?=\n\n|$)", re.S)
        block = f"<!--{anchor}-->\n{content}"
        if f"<!--{anchor}-->" in doc:
            doc = pat.sub(lambda m: block, doc, count=1)

    put("FIG3", csv_to_md(block_from_bench(bench, "Fig 3")))
    put("FIG4", csv_to_md(block_from_bench(bench, "Fig 4")))
    put("POOL", csv_to_md(block_from_bench(bench, "Pool scalability")))
    put("ROOFLINE", csv_to_md(roof))
    open("EXPERIMENTS.md", "w").write(doc)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
