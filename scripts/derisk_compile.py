"""De-risk: can XLA-CPU compile a 512-partition sharded scanned transformer?

Checks: jax.make_mesh with fake devices, pjit lower/compile, cost_analysis,
memory_analysis, collective ops visible in HLO text.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

D_MODEL = 1024
N_LAYERS = 8
VOCAB = 32000
BATCH = 256
SEQ = 1024


def init_specs():
    layer = {
        "wq": jax.ShapeDtypeStruct((N_LAYERS, D_MODEL, D_MODEL), jnp.bfloat16),
        "wo": jax.ShapeDtypeStruct((N_LAYERS, D_MODEL, D_MODEL), jnp.bfloat16),
        "wup": jax.ShapeDtypeStruct((N_LAYERS, D_MODEL, 4 * D_MODEL), jnp.bfloat16),
        "wdn": jax.ShapeDtypeStruct((N_LAYERS, 4 * D_MODEL, D_MODEL), jnp.bfloat16),
    }
    emb = jax.ShapeDtypeStruct((VOCAB, D_MODEL), jnp.bfloat16)
    return {"layers": layer, "emb": emb}


def param_shardings(mesh):
    layer = {
        "wq": NamedSharding(mesh, P(None, None, "model")),
        "wo": NamedSharding(mesh, P(None, "model", None)),
        "wup": NamedSharding(mesh, P(None, None, "model")),
        "wdn": NamedSharding(mesh, P(None, "model", None)),
    }
    emb = NamedSharding(mesh, P("model", None))
    return {"layers": layer, "emb": emb}


def fwd(params, tokens):
    x = params["emb"][tokens]  # (B, S, D)

    def body(x, lyr):
        h = jnp.einsum("bsd,de->bse", x, lyr["wq"])
        h = jnp.einsum("bse,ed->bsd", jax.nn.relu(h), lyr["wo"])
        x = x + h
        h = jnp.einsum("bsd,df->bsf", x, lyr["wup"])
        h = jnp.einsum("bsf,fd->bsd", jax.nn.relu(h), lyr["wdn"])
        return x + h, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["emb"])
    return logits


def loss_fn(params, tokens, labels):
    logits = fwd(params, tokens).astype(jnp.float32)
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, labels[..., None], axis=-1))


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    return params, loss


def main():
    print("devices:", len(jax.devices()))
    for shape, axes in [((16, 16), ("data", "model")), ((2, 16, 16), ("pod", "data", "model"))]:
        mesh = jax.make_mesh(shape, axes)
        batch_axes = ("data",) if len(shape) == 2 else (("pod", "data"),)
        ps = param_shardings(mesh)
        data_sh = NamedSharding(mesh, P(batch_axes[0], None))
        tok = jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32)
        t0 = time.perf_counter()
        with jax.set_mesh(mesh):
            jitted = jax.jit(
                train_step,
                in_shardings=(ps, data_sh, data_sh),
                out_shardings=(ps, NamedSharding(mesh, P())),
            )
            lowered = jitted.lower(init_specs(), tok, tok)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        print(f"mesh {shape}: lower {t1-t0:.1f}s compile {t2-t1:.1f}s")
        try:
            ma = compiled.memory_analysis()
            print("  memory_analysis:", ma)
        except Exception as e:  # noqa
            print("  memory_analysis failed:", e)
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            print("  cost flops:", ca.get("flops"), "bytes:", ca.get("bytes accessed"))
        except Exception as e:  # noqa
            print("  cost_analysis failed:", e)
        txt = compiled.as_text()
        import re
        colls = re.findall(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", txt)
        from collections import Counter
        print("  collectives:", Counter(colls))


if __name__ == "__main__":
    main()
