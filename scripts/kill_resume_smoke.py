#!/usr/bin/env python
"""Kill -9 + resume smoke: the durability contract, end to end.

Three legs, all against real processes (not in-process simulations):

1. **Driver kill/resume.** Launch ``repro.launch.evolve ea --fused`` with
   ``--snapshot-every``, SIGKILL it once the first snapshot lands, rerun
   with ``--resume``, and assert the final ``best``/``epochs`` line equals
   an uninterrupted run of the same seed. (If the victim wins the race and
   finishes before the kill, the resume leg still runs — resuming a
   completed run is a no-op that must reproduce the same final state.)
2. **Journaled PoolServer kill/restart.** SIGKILL a subprocess that is
   streaming PUTs into a journaled server (so the journal has a real torn
   tail), rehydrate with ``resume=True``, and assert exactly-once
   ``get_since`` semantics across a *second* restart: no seq delivered
   twice to the same cursor_id, and dropped + delivered accounts for every
   seq the cursor passed.
3. **Elastic resume.** Resume leg 1's checkpoint at double the island
   count and assert the run completes.
4. **Networked service kill/restart under load.** SIGKILL a
   ``python -m repro.server`` subprocess while a burst of wire PUTs is
   in flight (torn WAL tails across two shards), restart it with
   ``--resume``, and assert the rehydrated service answers with the same
   accepted entries and that a wire ``get_since`` under a named cursor
   never re-delivers a ``(shard, seq)`` across restarts — the leg-2
   contract, now across a process boundary and the HTTP frontend.

Run from the repo root:  python scripts/kill_resume_smoke.py
"""
from __future__ import annotations

import os
import re
import signal
import subprocess
import sys
import tempfile
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src"),
       "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}

EA_ARGS = ["--problem", "trap", "--islands", "4", "--epochs", "12",
           "--fused", "--seed", "7", "--max-pop", "32", "--min-pop", "32",
           "--gens-per-epoch", "4"]


def evolve_cmd(*extra: str) -> list:
    return [sys.executable, "-m", "repro.launch.evolve", "ea",
            *EA_ARGS, *extra]


def final_line(out: str) -> str:
    m = re.search(r"^final (best=.*)$", out, re.M)
    if not m:
        raise SystemExit(f"no final-state line in output:\n{out}")
    return m.group(1)


def run(cmd, **kw) -> str:
    r = subprocess.run(cmd, env=ENV, cwd=ROOT, capture_output=True,
                       text=True, timeout=600, **kw)
    if r.returncode != 0:
        raise SystemExit(f"{' '.join(cmd)} failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


def wait_for_snapshot(snap_dir: str, proc, timeout: float = 300.0) -> bool:
    """True once a published step dir exists; False if the victim finished
    first (won the race) — both are valid smoke states."""
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout:
        if any(re.fullmatch(r"step_\d+", n)
               for n in (os.listdir(snap_dir) if os.path.isdir(snap_dir)
                         else [])):
            return True
        if proc.poll() is not None:
            return False
        time.sleep(0.05)
    raise SystemExit("timed out waiting for first snapshot")


def leg1_driver_kill_resume(snap_dir: str) -> None:
    reference = final_line(run(evolve_cmd()))
    victim = subprocess.Popen(
        evolve_cmd("--snapshot-every", "2", "--snapshot-dir", snap_dir),
        env=ENV, cwd=ROOT, stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL)
    if wait_for_snapshot(snap_dir, victim):
        victim.send_signal(signal.SIGKILL)
        print("leg1: victim SIGKILLed after first snapshot")
    else:
        print("leg1: victim finished before kill — resume is a no-op replay")
    victim.wait()
    resumed = final_line(run(evolve_cmd(
        "--snapshot-every", "2", "--snapshot-dir", snap_dir, "--resume")))
    assert resumed == reference, (
        f"resume diverged:\n  uninterrupted: {reference}\n"
        f"  resumed:       {resumed}")
    print(f"leg1 OK: {resumed}")


PUT_STREAMER = r"""
import os, sys, time
import numpy as np
sys.path.insert(0, os.path.join({root!r}, "src"))
from repro.core import PoolServer
s = PoolServer(capacity=16, journal_path={journal!r}, resume=True)
i = 0
while True:
    s.put(np.full(8, i % 127, np.int8), float(i), uuid=i % 5)
    i += 1
    time.sleep(0.002)
"""


def leg2_server_kill_restart(journal: str) -> None:
    streamer = subprocess.Popen(
        [sys.executable, "-c",
         PUT_STREAMER.format(root=ROOT, journal=journal)],
        env=ENV, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < 120:
        if os.path.exists(journal) and sum(1 for _ in open(journal)) >= 50:
            break
        if streamer.poll() is not None:
            raise SystemExit("put streamer died before writing 50 records")
        time.sleep(0.05)
    streamer.send_signal(signal.SIGKILL)
    streamer.wait()
    print("leg2: streamer SIGKILLed mid-PUT")

    sys.path.insert(0, os.path.join(ROOT, "src"))
    import numpy as np
    from repro.core import PoolServer

    s1 = PoolServer(capacity=16, journal_path=journal, resume=True)
    st = s1.stats()
    assert st["size"] == 16 and st["puts"] >= 50, st
    got1, cur1, drop1 = s1.get_since(-1, limit=1000, cursor_id="smoke")
    seqs1 = [e.seq for e in got1]
    assert len(set(seqs1)) == len(seqs1), "duplicate seqs in one drain"
    assert len(seqs1) + drop1 == cur1 + 1, "dropped accounting is off"
    for i in range(5):
        s1.put(np.zeros(8, np.int8), 1000.0 + i, uuid=99)
    s1.close()

    # second restart: the named cursor must survive the journal replay —
    # a consumer that lost its own position (seq=-1) still never sees an
    # entry twice
    s2 = PoolServer(capacity=16, journal_path=journal, resume=True)
    got2, cur2, drop2 = s2.get_since(-1, limit=1000, cursor_id="smoke")
    seqs2 = [e.seq for e in got2]
    dup = set(seqs1) & set(seqs2)
    assert not dup, f"exactly-once violated across restart: {sorted(dup)}"
    assert len(seqs2) == 5 and drop2 == 0, (seqs2, drop2)
    assert cur2 + 1 == len(seqs1) + len(seqs2) + drop1 + drop2, \
        "cursor arithmetic leaks seqs across restart"
    print(f"leg2 OK: drain1={len(seqs1)} dropped1={drop1} "
          f"drain2={len(seqs2)} (no duplicates across restart)")


def leg3_elastic_resume(snap_dir: str) -> None:
    out = final_line(run(evolve_cmd(
        "--snapshot-dir", snap_dir, "--resume", "--islands", "8")))
    print(f"leg3 OK (4-island checkpoint resumed as 8): {out}")


def _spawn_service(spool: str, port: int = 0) -> tuple:
    """Start `python -m repro.server --resume` on an ephemeral port;
    returns (proc, url)."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.server", "--port", str(port),
         "--spool", spool, "--resume", "--shards", "2",
         "--capacity", "64"],
        env=ENV, cwd=ROOT, stdout=subprocess.PIPE, text=True)
    line = proc.stdout.readline()
    if "listening on" not in line:
        proc.kill()
        raise SystemExit(f"service failed to start: {line!r}")
    return proc, line.rsplit(" ", 1)[-1].strip()


def leg4_service_kill_restart(spool: str) -> None:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    import threading

    import numpy as np
    from repro.core.async_pool import PoolUnavailable
    from repro.server.client import RemotePoolServer

    proc, url = _spawn_service(spool)
    putter_lost = []

    def put_burst(n=10_000):
        c = RemotePoolServer(url, experiment="smoke4", client_id="burst")
        for i in range(n):
            try:
                c.put(np.full(8, i % 127, np.int8), float(i), uuid=i % 7)
            except PoolUnavailable:
                putter_lost.append(i)   # the kill landed mid-burst
                return

    burst = threading.Thread(target=put_burst, daemon=True)
    burst.start()

    # drain exactly-once while the burst is running, then kill mid-flight
    drain = RemotePoolServer(url, experiment="smoke4", client_id="drain")
    cursor, seen, pre_dropped = -1, set(), 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < 120:
        entries, cursor, d = drain.get_since(cursor, limit=64,
                                             cursor_id="smoke4")
        pre_dropped += d
        for e in entries:
            key = (e.shard, e.seq)
            assert key not in seen, f"duplicate {key} before restart"
            seen.add(key)
        if len(seen) >= 100:
            break
        time.sleep(0.01)
    assert len(seen) >= 100, f"burst too slow: {len(seen)} drained"
    proc.send_signal(signal.SIGKILL)
    proc.wait()
    proc.stdout.close()
    burst.join(timeout=60)
    print(f"leg4: service SIGKILLed mid-burst "
          f"({len(seen)} drained, torn WAL tails possible)")

    # restart with --resume: WAL rehydration across both shards, then the
    # same named cursor must pick up where it left off — a drain that
    # lost its own position (seq=-1) still never re-sees a (shard, seq)
    proc2, url2 = _spawn_service(spool)
    try:
        drain2 = RemotePoolServer(url2, experiment="smoke4",
                                  client_id="drain")
        st = drain2.stats()
        assert st["shards"] == 2 and st["puts"] >= 100, st
        assert st["size"] >= 1, "rehydrated service lost the pool"
        got, cur2, dropped2 = drain2.get_since(-1, limit=10_000,
                                               cursor_id="smoke4")
        dup = {(e.shard, e.seq) for e in got} & seen
        assert not dup, (f"exactly-once violated across service restart: "
                         f"{sorted(dup)[:5]}")
        covered = sum(c + 1 for c in cur2)
        # the full ledger: everything the cursor passed is either in a
        # drain or counted dropped (ring eviction outpacing the drain)
        total_dropped = pre_dropped + dropped2
        assert covered == len(seen) + len(got) + total_dropped, (
            f"cursor ledger leaks: covered={covered} "
            f"pre={len(seen)} post={len(got)} dropped={total_dropped}")
        drain2.close()
    finally:
        proc2.send_signal(signal.SIGTERM)
        try:
            proc2.wait(timeout=15)
        except subprocess.TimeoutExpired:
            proc2.kill()
            proc2.wait()
        proc2.stdout.close()
    print(f"leg4 OK: resume rehydrated {st['puts']} puts across "
          f"{st['shards']} shards; post-restart drain {len(got)} "
          f"dropped {total_dropped}, no (shard, seq) seen twice")


def main() -> None:
    with tempfile.TemporaryDirectory() as tmp:
        snap_dir = os.path.join(tmp, "snaps")
        leg1_driver_kill_resume(snap_dir)
        leg2_server_kill_restart(os.path.join(tmp, "pool.jsonl"))
        leg3_elastic_resume(snap_dir)
        leg4_service_kill_restart(os.path.join(tmp, "spool"))
    print("kill_resume_smoke: all legs passed")


if __name__ == "__main__":
    main()
