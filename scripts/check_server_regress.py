#!/usr/bin/env python
"""Server-load regression gate: fail CI if the fresh server smoke lost
>30% requests/sec against the committed BENCH_server.json on a
comparable host.

Rows are matched on (scenario, clients, workers, shards) and only
compared when baseline and fresh were measured with the same cpu_count —
wire throughput on this repo's 1-core container and on a multi-core CI
runner are different universes, and a cross-host comparison would gate
on hardware, not on code. Unmatched rows are reported but never fail, so
adding scenarios doesn't require regenerating the baseline first.

The exactly-once ledger is NOT host-dependent and is always enforced:
any fresh row with duplicates, a non-balancing cursor ledger, or
``dropped != 0`` fails the gate regardless of host.

Usage:
    python scripts/check_server_regress.py \
        --baseline BENCH_server.json --fresh /tmp/fresh_server.json \
        [--threshold 0.30]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Tuple


def _key(row: Dict[str, Any]) -> Tuple:
    return (row["scenario"], row["clients"], row["workers"], row["shards"])


def _env(payload: Dict[str, Any]) -> Tuple:
    return (payload.get("host", {}).get("cpu_count"),)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default="BENCH_server.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional requests/sec drop "
                         "(0.30 = fail below 70%% of baseline)")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        base = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    failures = []
    # correctness gate first: host-independent, never skipped
    for row in fresh.get("rows", []):
        problems = []
        if not row.get("exactly_once_ok", False):
            problems.append("ledger does not balance")
        if row.get("duplicates", 0):
            problems.append(f"{row['duplicates']} duplicate (shard,seq)")
        if row.get("dropped", 0):
            problems.append(f"{row['dropped']} entries dropped")
        if problems:
            print(f"server gate: {_key(row)}: EXACTLY-ONCE BROKEN — "
                  f"{'; '.join(problems)}")
            failures.append(_key(row))
        else:
            print(f"server gate: {_key(row)}: exactly-once OK "
                  f"(delivered {row.get('delivered')}, dropped 0)")

    if _env(base) != _env(fresh):
        print(f"server gate: throughput SKIP — baseline cpu_count "
              f"{_env(base)} != fresh {_env(fresh)} (cross-host wire "
              f"throughput is not comparable)")
        return 1 if failures else 0

    base_rows = {_key(r): r for r in base.get("rows", [])}
    compared = 0
    for row in fresh.get("rows", []):
        ref = base_rows.get(_key(row))
        if ref is None:
            print(f"server gate: new row (no baseline): {_key(row)}")
            continue
        compared += 1
        floor = ref["requests_per_sec"] * (1.0 - args.threshold)
        status = ("OK" if row["requests_per_sec"] >= floor else "REGRESSED")
        print(f"server gate: {row['scenario']:>10s} "
              f"{row['clients']:>6d} clients: "
              f"{row['requests_per_sec']:>8.0f} req/s vs baseline "
              f"{ref['requests_per_sec']:>8.0f} (floor {floor:>8.0f}) "
              f"{status}")
        if status == "REGRESSED":
            failures.append(_key(row))

    if failures:
        print(f"server gate: FAIL — {len(failures)} row(s): {failures}")
        return 1
    if not compared:
        print("server gate: throughput SKIP — no comparable rows")
        return 0
    print(f"server gate: OK — {compared} row(s) within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
