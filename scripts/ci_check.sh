#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast benchmark smoke.
#
#   scripts/ci_check.sh            # from anywhere inside the repo
#
# KNOWN_FAILING lists modules with pre-existing breakage excluded from the
# gate. Currently EMPTY: the jax-0.4.37 API-drift quarantine (AbstractMesh /
# get_abstract_mesh / set_mesh / shard_map drift) was burned down by the
# repro.compat shims — the gate is strict on the whole suite. Add entries
# only with a tracking note in ROADMAP.md.
#
# The benchmark smoke runs the pool + migration + speed sections only
# (fig3 replays paper-scale evolution and roofline's dry-run section needs
# dry-run artifacts; fig4 runs in --smoke trim below) and leaves two
# machine-readable records behind:
#   BENCH_migration.json — epochs/sec per registered topology via the
#     fused driver, the bench_async sync-vs-async-under-churn section,
#     and the bench_acceptance policy x topology sweep;
#   BENCH_speed.json — the paper-style speed baseline (evals/sec +
#     time-to-solution per problem x genome length x generation-engine
#     impl, jnp vs pallas vs pallas_tiled) + the generation-roofline
#     section, two scenarios in smoke trim.
# Both carry "host" + "host.env" blocks (jax version/backend/device,
# XLA_FLAGS, interpret mode, autotune cache) so numbers are attributable.
# A third committed artifact, BENCH_server.json (networked pool service
# load harness), is gated by scripts/check_server_regress.py from a
# 500-volunteer smoke; its 10k-volunteer headline row is refreshed only
# by explicit `python benchmarks/server_load.py --full` runs.
# BENCH_speed.json is a *committed* artifact: the fresh smoke is written
# to a temp file and gated against the committed baseline (>30% evals/sec
# regression on the same backend fails) before replacing it locally.
# The GA kernel smokes below prove the fused generation megakernel —
# single-tile AND grid-tiled (>=2x2x2 grid) — bit-exact against the jnp
# oracle in interpret mode before any benchmark touches it.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"

KNOWN_FAILING=()

echo "== repro-lint (AST invariant analyzer: RNG/lock/purity/registry/donation) =="
# selfcheck first: a silently broken analyzer must not green-light the tree
python -m repro.analysis --selfcheck
python -m repro.analysis --format github --baseline analysis_baseline.json \
    src/ benchmarks/ examples/

echo "== tier-1 tests =="
python -m pytest -x -q ${KNOWN_FAILING[@]+"${KNOWN_FAILING[@]/#/--ignore=}"}

echo "== GA generation-kernel interpret smoke (pallas vs jnp oracle) =="
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.core import EAConfig, make_rastrigin, make_trap
from repro.kernels import ga as gk

for problem, cx in ((make_trap(n_traps=8, l=4), "two_point"),
                    (make_rastrigin(dim=16), "blend")):
    cfg = EAConfig(max_pop=32, min_pop=16, crossover=cx)
    pop = problem.init_population(jax.random.key(0), 32)
    fit = problem.evaluate(problem.consts, pop)
    args = (jax.random.key(1), pop, fit, jnp.int32(24), cfg, problem.genome)
    got = gk.generation(*args, interpret=True)
    want = gk.generation_ref(*args)
    if problem.genome.kind == "binary":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)
    gp, gf = gk.generation_eval(*args, problem.fused, interpret=True)
    np.testing.assert_allclose(np.asarray(gf),
                               np.asarray(problem.evaluate(problem.consts,
                                                           gp)),
                               rtol=1e-5, atol=1e-4)
    # tiled streaming engine forced through a >=2x2x2 grid: bit-identical
    # to the untiled kernel (binary: also to the oracle) for any tiling
    for tp, tl in ((16, 8), (8, 16)):
        tgot = gk.generation_tiled(*args, interpret=True,
                                   tile_pop=tp, tile_len=tl)
        np.testing.assert_array_equal(np.asarray(tgot), np.asarray(got))
        tgp, tgf = gk.generation_eval_tiled(*args, problem.fused,
                                            interpret=True, tile_pop=tp,
                                            tile_len=tl)
        np.testing.assert_array_equal(np.asarray(tgp), np.asarray(gp))
        np.testing.assert_allclose(np.asarray(tgf), np.asarray(gf),
                                   rtol=1e-5, atol=1e-4)
    print(f"  {problem.name}: generation + fused-eval + tiled-grid "
          "parity OK")
PY

echo "== kill -9 + resume smoke (segmented drivers + journaled PoolServer) =="
python scripts/kill_resume_smoke.py

echo "== observability smoke (traced volunteer_sim: trace parses, ledger balances) =="
# Async + churn exercises every counter; the timeline CLI exits 1 on an
# unbalanced ledger. obs_trace.json is uploaded as a CI artifact so a
# red run can be dropped straight into Perfetto (docs/observability.md).
python examples/volunteer_sim.py --runtime async --churn 0.4 --ticks 12 \
    --trace obs_trace.json --obs-json obs_counters.json
python - <<'PY'
import json
trace = json.load(open("obs_trace.json"))
events = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
assert events, "traced run recorded no spans"
assert any(e["name"] == "driver.tick" for e in events), "no driver.tick spans"
obs = json.load(open("obs_counters.json"))
t = obs["totals"]
assert t["delivered"] == t["accepted"] + t["rejected"], f"ledger broken: {t}"
print(f"  obs smoke: {len(events)} spans, ledger "
      f"delivered={t['delivered']} accepted={t['accepted']} "
      f"rejected={t['rejected']} balanced OK")
PY
python -m repro.obs obs_trace.json --obs obs_counters.json

echo "== server load smoke (500 volunteers over the wire) + regression gate =="
# BENCH_server.json is a *committed* artifact whose headline row (10k
# volunteers) only a deliberate `benchmarks/server_load.py --full` run can
# regenerate — so unlike the speed flow, the fresh smoke is gated and then
# DISCARDED, never promoted over the baseline.
FRESH_SERVER="$(mktemp /tmp/bench_server_fresh.XXXXXX.json)"
python benchmarks/server_load.py --scenario smoke --json "$FRESH_SERVER"
if [[ -f BENCH_server.json ]]; then
    python scripts/check_server_regress.py --baseline BENCH_server.json \
        --fresh "$FRESH_SERVER" --threshold 0.30
else
    echo "no committed BENCH_server.json — first run, gate skipped"
fi
rm -f "$FRESH_SERVER"

echo "== Fig 4 smoke (tiled generation engine end-to-end) =="
python -m benchmarks.fig4_f15 --smoke

echo "== benchmark smoke (pool + migration + async + acceptance + speed) =="
FRESH_SPEED="$(mktemp /tmp/bench_speed_fresh.XXXXXX.json)"
python -m benchmarks.run --skip fig3 fig4 roofline --speed-json "$FRESH_SPEED"

echo "== speed-regression gate (fresh smoke vs committed BENCH_speed.json) =="
if [[ -f BENCH_speed.json ]]; then
    python scripts/check_speed_regress.py --baseline BENCH_speed.json \
        --fresh "$FRESH_SPEED" --threshold 0.30
else
    echo "no committed BENCH_speed.json — first run, gate skipped"
fi
mv "$FRESH_SPEED" BENCH_speed.json

echo "ci_check: OK"
