#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast benchmark smoke.
#
#   scripts/ci_check.sh            # from anywhere inside the repo
#
# KNOWN_FAILING lists modules with pre-existing jax-version breakage in
# model/sharding-land (AbstractMesh / pjit API drift — tracked in
# ROADMAP.md); they are excluded so the gate is strict on everything else.
# Remove entries as they get fixed.
#
# The benchmark smoke runs the pool + migration sections only (fig3/fig4
# replay paper-scale evolution and roofline needs dry-run artifacts) and
# leaves BENCH_migration.json behind as the machine-readable throughput
# record (epochs/sec per registered topology via the fused driver).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"

KNOWN_FAILING=(
    tests/test_dryrun_small.py
    tests/test_models_smoke.py
    tests/test_moe_ep.py
    tests/test_optim.py
    tests/test_serve_consistency.py
    tests/test_shardings.py
    tests/test_system.py
)

echo "== tier-1 tests (minus known model-land breakage) =="
python -m pytest -x -q "${KNOWN_FAILING[@]/#/--ignore=}"

echo "== benchmark smoke (pool + migration) =="
python -m benchmarks.run --skip fig3 fig4 roofline

echo "ci_check: OK"
