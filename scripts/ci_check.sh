#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast benchmark smoke.
#
#   scripts/ci_check.sh            # from anywhere inside the repo
#
# KNOWN_FAILING lists modules with pre-existing breakage excluded from the
# gate. Currently EMPTY: the jax-0.4.37 API-drift quarantine (AbstractMesh /
# get_abstract_mesh / set_mesh / shard_map drift) was burned down by the
# repro.compat shims — the gate is strict on the whole suite. Add entries
# only with a tracking note in ROADMAP.md.
#
# The benchmark smoke runs the pool + migration sections only (fig3/fig4
# replay paper-scale evolution and roofline needs dry-run artifacts) and
# leaves BENCH_migration.json behind as the machine-readable throughput
# record: epochs/sec per registered topology via the fused driver, the
# bench_async sync-vs-async-under-churn section (degenerate / heterogeneous
# / heterogeneous+churn operating points of the async runtime), and the
# bench_acceptance policy x topology sweep (epochs/sec + mean pairwise
# pool-distance diversity per acceptance policy) so CI exercises the
# acceptance engine end-to-end on every run.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"

KNOWN_FAILING=()

echo "== tier-1 tests =="
python -m pytest -x -q ${KNOWN_FAILING[@]+"${KNOWN_FAILING[@]/#/--ignore=}"}

echo "== benchmark smoke (pool + migration + async + acceptance) =="
python -m benchmarks.run --skip fig3 fig4 roofline

echo "ci_check: OK"
