#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast benchmark smoke.
#
#   scripts/ci_check.sh            # from anywhere inside the repo
#
# KNOWN_FAILING lists modules with pre-existing breakage excluded from the
# gate. Currently EMPTY: the jax-0.4.37 API-drift quarantine (AbstractMesh /
# get_abstract_mesh / set_mesh / shard_map drift) was burned down by the
# repro.compat shims — the gate is strict on the whole suite. Add entries
# only with a tracking note in ROADMAP.md.
#
# The benchmark smoke runs the pool + migration + speed sections only
# (fig3/fig4 replay paper-scale evolution and roofline needs dry-run
# artifacts) and leaves two machine-readable records behind:
#   BENCH_migration.json — epochs/sec per registered topology via the
#     fused driver, the bench_async sync-vs-async-under-churn section,
#     and the bench_acceptance policy x topology sweep;
#   BENCH_speed.json — the paper-style speed baseline (evals/sec +
#     time-to-solution per problem x genome length x generation-engine
#     impl, jnp vs pallas), two scenarios in smoke trim.
# Both carry a "host" block (jax version/backend/device) so numbers are
# attributable. The GA kernel smoke below proves the fused generation
# megakernel (interpret mode) bit-exact against its jnp oracle before any
# benchmark touches it.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"

KNOWN_FAILING=()

echo "== tier-1 tests =="
python -m pytest -x -q ${KNOWN_FAILING[@]+"${KNOWN_FAILING[@]/#/--ignore=}"}

echo "== GA generation-kernel interpret smoke (pallas vs jnp oracle) =="
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.core import EAConfig, make_rastrigin, make_trap
from repro.kernels import ga as gk

for problem, cx in ((make_trap(n_traps=8, l=4), "two_point"),
                    (make_rastrigin(dim=16), "blend")):
    cfg = EAConfig(max_pop=32, min_pop=16, crossover=cx)
    pop = problem.init_population(jax.random.key(0), 32)
    fit = problem.evaluate(problem.consts, pop)
    args = (jax.random.key(1), pop, fit, jnp.int32(24), cfg, problem.genome)
    got = gk.generation(*args, interpret=True)
    want = gk.generation_ref(*args)
    if problem.genome.kind == "binary":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)
    gp, gf = gk.generation_eval(*args, problem.fused, interpret=True)
    np.testing.assert_allclose(np.asarray(gf),
                               np.asarray(problem.evaluate(problem.consts,
                                                           gp)),
                               rtol=1e-5, atol=1e-4)
    print(f"  {problem.name}: generation + fused-eval parity OK")
PY

echo "== benchmark smoke (pool + migration + async + acceptance + speed) =="
python -m benchmarks.run --skip fig3 fig4 roofline

echo "ci_check: OK"
