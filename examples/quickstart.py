"""Quickstart: solve the paper's 40-trap with 8 pooled islands on CPU.

    PYTHONPATH=src python examples/quickstart.py

This is Figure 1 of the paper in ~10 lines of user code: islands evolve
autonomously for 100 generations, PUT their best into the pool, GET a
random immigrant, repeat — until someone finds the all-ones string.
"""
import jax

from repro.core import EAConfig, MigrationConfig, make_trap, run_experiment


def main():
    problem = make_trap(n_traps=40, l=4, a=1.0, b=2.0, z=3.0)
    cfg = EAConfig(max_pop=256, min_pop=128,        # W² heterogeneous pops
                   generations_per_epoch=100,        # the paper's n
                   mutation_rate=1.0 / 160)
    result = run_experiment(
        problem, cfg, MigrationConfig(pool_capacity=64),
        n_islands=8, max_epochs=60, rng=jax.random.key(0), verbose=True)

    print()
    print(f"solved: {result.success}")
    print(f"evaluations to solution: {result.evaluations_to_solution:,}"
          if result.success else f"best: "
          f"{float(result.islands.best_fitness.max())}/80")
    print(f"wall time: {result.wall_time_s:.1f}s "
          f"({result.epochs} epochs x 100 generations x 8 islands)")


if __name__ == "__main__":
    main()
