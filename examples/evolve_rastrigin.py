"""Pooled evolution on the paper's hard floating-point problem (CEC2010
F15: shifted, group-rotated Rastrigin) — reduced dimension for CPU demo.

    PYTHONPATH=src python examples/evolve_rastrigin.py [--dim 100]
    PYTHONPATH=src python examples/evolve_rastrigin.py --impl pallas

Shows the float-genome path: BLX crossover + gaussian mutation, pool
migration, fitness = -F15 (maximized; 0 is the global optimum at x = o).

``--impl`` selects the generation-operator engine (the fifth engine axis,
``EAConfig.impl`` -> repro.kernels.ga registry):

* ``jnp``    — the classic four-op jax.random path (default);
* ``pallas`` — the fused selection->crossover->mutation megakernel with
  on-chip counter RNG (VMEM-resident genome tiles; interpret-mode
  emulation off-TPU, so on CPU expect *slower* — the knob demonstrates
  engine-swap transparency, the TPU is where it pays);
* ``pallas_ref`` — the megakernel's pure-jnp oracle (same random stream
  as 'pallas'; bit-exact against it in interpret mode).

To *measure* the engines against each other, run the paper-style speed
harness::

    PYTHONPATH=src python -m benchmarks.speed_baseline [--full]

which writes ``BENCH_speed.json``. How to read it: each row is one
(problem x genome_length x impl) cell; ``evals_per_sec`` is the
cross-language throughput metric of the source paper's tables (mean over
seeded runs, compile excluded by a warm-up run), ``success_rate`` /
``time_to_solution_s`` / ``evals_to_solution`` are the Fig-3-style
to-solution metrics, and the top-level ``host`` block (jax version,
backend, device kind) says what hardware the numbers belong to —
compare rows only within a matching host block.
"""
import argparse

import jax

from repro.core import EAConfig, MigrationConfig, make_f15, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=100)
    ap.add_argument("--group", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--islands", type=int, default=8)
    ap.add_argument("--impl", default="jnp",
                    choices=["jnp", "pallas", "pallas_ref"],
                    help="generation-operator engine (see module docstring)")
    args = ap.parse_args()

    problem = make_f15(jax.random.key(7), dim=args.dim, group=args.group)
    cfg = EAConfig(max_pop=256, min_pop=128, generations_per_epoch=50,
                   crossover="blend", mutation_rate=4.0 / args.dim,
                   mutation_sigma=0.5, tournament_k=3,
                   max_evaluations=20_000_000, impl=args.impl)
    result = run_experiment(problem, cfg, MigrationConfig(),
                            n_islands=args.islands, max_epochs=args.epochs,
                            rng=jax.random.key(1), verbose=True,
                            stop_on_success=False)
    best = float(result.islands.best_fitness.max())
    print(f"\nbest F15 value reached: {-best:.4f} (0 = global optimum)")
    print(f"evaluations: {result.evaluations:,} "
          f"wall: {result.wall_time_s:.1f}s (impl={args.impl})")


if __name__ == "__main__":
    main()
