"""Pooled evolution on the paper's hard floating-point problem (CEC2010
F15: shifted, group-rotated Rastrigin) — reduced dimension for CPU demo.

    PYTHONPATH=src python examples/evolve_rastrigin.py [--dim 100]

Shows the float-genome path: BLX crossover + gaussian mutation, pool
migration, fitness = -F15 (maximized; 0 is the global optimum at x = o).
"""
import argparse

import jax

from repro.core import EAConfig, MigrationConfig, make_f15, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=100)
    ap.add_argument("--group", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--islands", type=int, default=8)
    args = ap.parse_args()

    problem = make_f15(jax.random.key(7), dim=args.dim, group=args.group)
    cfg = EAConfig(max_pop=256, min_pop=128, generations_per_epoch=50,
                   crossover="blend", mutation_rate=4.0 / args.dim,
                   mutation_sigma=0.5, tournament_k=3,
                   max_evaluations=20_000_000)
    result = run_experiment(problem, cfg, MigrationConfig(),
                            n_islands=args.islands, max_epochs=args.epochs,
                            rng=jax.random.key(1), verbose=True,
                            stop_on_success=False)
    best = float(result.islands.best_fitness.max())
    print(f"\nbest F15 value reached: {-best:.4f} (0 = global optimum)")
    print(f"evaluations: {result.evaluations:,} "
          f"wall: {result.wall_time_s:.1f}s")


if __name__ == "__main__":
    main()
