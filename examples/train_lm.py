"""End-to-end LM training driver: train a ~100M-param model for a few
hundred steps on the synthetic corpus, with checkpoints + resume.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-sized

The synthetic corpus has real conditional structure (see repro.data), so
cross-entropy drops well below uniform — the printed curve is the proof
the whole stack (model/optimizer/data/checkpoint) trains.
"""
import argparse
import dataclasses
import os
import tempfile

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                         "repro_train_lm")
    if args.tiny:
        steps = args.steps or 60
        state, losses = train(args.arch, smoke=True, steps=steps, batch=8,
                              seq=64, lr=3e-3, ckpt_dir=ckpt,
                              ckpt_every=max(steps // 2, 1),
                              resume=args.resume)
    else:
        # ~100M: scale the arch family to a 12-layer/768-wide variant
        from repro.models import build_model
        cfg = get_config(args.arch, smoke=True)
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
            head_dim=64, d_ff=2048, vocab_size=32000,
            name=cfg.name + "-100m")
        print(f"config: {cfg.name}  params ~ "
              f"{build_model(cfg).param_count()/1e6:.0f}M")
        import repro.launch.train as T

        def cfg_get(name, smoke=True):
            return cfg

        T.get_config = cfg_get   # inject the scaled config
        steps = args.steps or 300
        state, losses = T.train(args.arch, smoke=True, steps=steps,
                                batch=16, seq=256, lr=6e-4, ckpt_dir=ckpt,
                                ckpt_every=100, resume=args.resume)
    import math
    uniform = math.log(32000 if not args.tiny else 256)
    print(f"\nce curve: start {losses[0]:.3f} -> end {losses[-1]:.3f} "
          f"(uniform = {uniform:.2f})")
    assert losses[-1] < losses[0], "training must reduce loss"
    print(f"checkpoints in {ckpt} (rerun with --resume to continue)")


if __name__ == "__main__":
    main()
