"""Pods-as-islands: NodIO pool-based training of an assigned LM arch.

    PYTHONPATH=src python examples/evolve_lm.py

Four members (think: four pods) train smoke-size minicpm replicas with
chromosome-encoded (lr, weight_decay). Every epoch each member PUTs its
(hypers, -val_loss, weights) into the PoolServer and GETs a random member —
adopting + perturbing when the sample is fitter. Mid-run the server dies
for two epochs: training continues, migration pauses, nothing crashes.
"""
from repro.core import PoolServer
from repro.launch.evolve import run_pbt


def main():
    ctrl = run_pbt(arch="minicpm-2b", members=4, epochs=6,
                   steps_per_epoch=15, batch=8, seq=64, verbose=True)
    # fault injection demo: kill the pool and keep training
    print("\nkilling the pool server; members continue standalone:")
    ctrl.pool.kill()
    from repro.data import SyntheticLM
    from repro.configs import get_config
    cfg = get_config("minicpm-2b", smoke=True)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    m = ctrl.members[0]
    stats = ctrl.train_epoch(m, (data.batch_for_step(s, 0, 1)
                                 for s in range(10)),
                             data.batch_for_step(99_999, 0, 1))
    ok = ctrl.migrate(m)
    print(f"member 0 epoch with dead pool: val={stats['val_loss']:.4f} "
          f"migrated={ok} (expected False) — fault tolerance holds")


if __name__ == "__main__":
    main()
