"""Volunteer-fleet simulation: churn, server failure, stragglers — the
paper's fault-tolerance story made executable.

    PYTHONPATH=src python examples/volunteer_sim.py

Timeline:
  epoch  3: the pool server DIES          (islands keep evolving standalone)
  epoch  6: the server comes back          (migration resumes, state intact)
  epoch  8: 4 volunteers JOIN              (seeded from the pool, like
                                            opening the experiment URL)
  epoch 12: 6 volunteers LEAVE             (closed tabs; their best work
                                            survives inside the pool)
Also runs a StragglerMonitor over simulated heterogeneous hardware and
prints the per-worker work-scale the driver would apply.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EAConfig, MigrationConfig, make_trap
from repro.core import evolution, island as island_lib, pool as pool_lib
from repro.runtime import StragglerMonitor, grow_islands, shrink_islands


def main():
    problem = make_trap(n_traps=20, l=4)
    cfg = EAConfig(max_pop=128, min_pop=64, generations_per_epoch=50,
                   mutation_rate=1.0 / 80)
    mig = MigrationConfig(pool_capacity=64)
    rng = jax.random.key(0)

    k, rng = jax.random.split(rng)
    islands = island_lib.init_islands(k, 8, problem, cfg)
    pool = pool_lib.pool_init(mig.pool_capacity, problem.genome)
    mon = StragglerMonitor(threshold=2.0)

    def epoch(islands, pool, key, up):
        return jax.jit(
            lambda i, q, kk: evolution.epoch_step(
                i, q, kk, problem, cfg, mig, False, up))(islands, pool, key)

    for e in range(1, 16):
        up = not (3 <= e < 6)
        k, rng = jax.random.split(rng)
        t0 = time.perf_counter()
        islands, pool = epoch(islands, pool, k, up)
        mon.record(0, time.perf_counter() - t0)

        if e == 8:
            k, rng = jax.random.split(rng)
            islands = grow_islands(islands, 4, problem, cfg, pool, k)
            note = "+4 volunteers joined (pool-seeded)"
        elif e == 12:
            islands = shrink_islands(islands, 6)
            note = "-6 volunteers left (pool keeps their work)"
        else:
            note = ""
        best = float(islands.best_fitness.max())
        print(f"epoch {e:2d} [{'server UP ' if up else 'server DOWN'}] "
              f"islands={islands.pop.shape[0]:2d} best={best:5.1f}/40 "
              f"pool={int(pool.count):2d} {note}")
        if best >= 40.0:
            print("solution found — experiment over")
            break

    # straggler demo: simulated heterogeneous fleet
    print("\nstraggler mitigation (simulated heterogeneous volunteers):")
    mon2 = StragglerMonitor(threshold=1.5)
    speeds = {0: 1.0, 1: 1.1, 2: 0.9, 3: 4.0}   # worker 3 is a phone
    for _ in range(8):
        for w, s in speeds.items():
            mon2.record(w, s)
    for w in speeds:
        print(f"  worker {w}: work_scale={mon2.work_scale(w):.2f} "
              f"{'<- straggler: fewer generations/epoch' if w in mon2.stragglers() else ''}")


if __name__ == "__main__":
    main()
