"""Volunteer-fleet simulation: churn, server failure, stragglers — the
paper's fault-tolerance story made executable.

    PYTHONPATH=src python examples/volunteer_sim.py

Timeline:
  epoch  3: the pool server DIES          (islands keep evolving standalone)
  epoch  6: the server comes back          (migration resumes, state intact)
  epoch  8: 4 volunteers JOIN              (seeded from the pool, like
                                            opening the experiment URL)
  epoch 12: 6 volunteers LEAVE             (closed tabs; their best work
                                            survives inside the pool)
A host PoolServer runs alongside with two browser-style PoolClient
volunteers; a HostBridge (core.migration) syncs it with the device pool
every epoch — device islands and host volunteers share one experiment.
Also runs a StragglerMonitor over simulated heterogeneous hardware and
prints the per-worker work-scale the driver would apply.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EAConfig, HostBridge, MigrationConfig, PoolClient,
                        PoolServer, make_trap)
from repro.core import evolution, island as island_lib, pool as pool_lib
from repro.runtime import StragglerMonitor, grow_islands, shrink_islands


def main():
    problem = make_trap(n_traps=20, l=4)
    cfg = EAConfig(max_pop=128, min_pop=64, generations_per_epoch=50,
                   mutation_rate=1.0 / 80)
    mig = MigrationConfig(pool_capacity=64)
    rng = jax.random.key(0)

    k, rng = jax.random.split(rng)
    islands = island_lib.init_islands(k, 8, problem, cfg)
    pool = pool_lib.pool_init(mig.pool_capacity, problem.genome)
    mon = StragglerMonitor(threshold=2.0)

    # host side: a REST-semantics PoolServer, two volunteer clients and the
    # bridge that lets them join the device islands' experiment
    server = PoolServer(capacity=256, seed=1)
    volunteers = [PoolClient(server, uuid=100 + i) for i in range(2)]
    bridge = HostBridge(server, every=1, pull=2)
    vol_rng = np.random.default_rng(7)

    def volunteer_round():
        """Each volunteer hill-climbs a random genome a little and PUTs it
        (a browser tab doing one autonomous epoch)."""
        for v in volunteers:
            got = v.get_random()
            g = (got[0].copy() if got is not None
                 else vol_rng.integers(0, 2, problem.genome.length)
                 .astype(np.int8))
            flip = vol_rng.integers(0, g.size, 4)
            g[flip] = 1  # volunteers push toward the all-ones optimum
            f = float(problem.evaluate(problem.consts, g[None])[0])
            v.put(g, f)

    # one jitted step; up/e are traced args so epochs reuse a single compile
    epoch = jax.jit(lambda i, q, kk, up, e: evolution.epoch_step(
        i, q, kk, problem, cfg, mig, False, up, epoch=e))

    for e in range(1, 16):
        up = not (3 <= e < 6)
        if up:
            server.revive()
        else:
            server.kill()
        k, rng = jax.random.split(rng)
        t0 = time.perf_counter()
        islands, pool = epoch(islands, pool, k, up, jnp.int32(e))
        # sync first so the server is seeded with the device best before the
        # volunteers GET — a cold-start GET against an empty-but-up server
        # would otherwise read as a lost XHR
        pool = bridge.sync(pool, e)
        if up:
            volunteer_round()
        mon.record(0, time.perf_counter() - t0)

        if e == 8:
            k, rng = jax.random.split(rng)
            islands = grow_islands(islands, 4, problem, cfg, pool, k)
            note = "+4 volunteers joined (pool-seeded)"
        elif e == 12:
            islands = shrink_islands(islands, 6)
            note = "-6 volunteers left (pool keeps their work)"
        else:
            note = ""
        best = float(islands.best_fitness.max())
        print(f"epoch {e:2d} [{'server UP ' if up else 'server DOWN'}] "
              f"islands={islands.pop.shape[0]:2d} best={best:5.1f}/40 "
              f"pool={int(pool.count):2d} bridge={bridge.stats()} {note}")
        if best >= 40.0:
            print("solution found — experiment over")
            break
    print(f"volunteer lost XHRs: "
          f"{[(v.uuid, v.lost_puts + v.lost_gets) for v in volunteers]}")

    # straggler demo: simulated heterogeneous fleet
    print("\nstraggler mitigation (simulated heterogeneous volunteers):")
    mon2 = StragglerMonitor(threshold=1.5)
    speeds = {0: 1.0, 1: 1.1, 2: 0.9, 3: 4.0}   # worker 3 is a phone
    for _ in range(8):
        for w, s in speeds.items():
            mon2.record(w, s)
    for w in speeds:
        print(f"  worker {w}: work_scale={mon2.work_scale(w):.2f} "
              f"{'<- straggler: fewer generations/epoch' if w in mon2.stragglers() else ''}")


if __name__ == "__main__":
    main()
