"""Volunteer-fleet simulation: churn, server failure, stragglers — the
paper's fault-tolerance story made executable.

    PYTHONPATH=src python examples/volunteer_sim.py              # sync demo
    PYTHONPATH=src python examples/volunteer_sim.py --runtime async \
        --min-rate 0.25 --max-rate 1.0 --staleness 3 --churn 0.4
    PYTHONPATH=src python examples/volunteer_sim.py --runtime async \
        --server http://127.0.0.1:8040          # join a networked service
                                                # (python -m repro.server)

Sync timeline (the PR-1 demo, epoch-lockstep migration):
  epoch  3: the pool server DIES          (islands keep evolving standalone)
  epoch  6: the server comes back          (migration resumes, state intact)
  epoch  8: 4 volunteers JOIN              (seeded from the pool, like
                                            opening the experiment URL)
  epoch 12: 6 volunteers LEAVE             (closed tabs; their best work
                                            survives inside the pool)

Async runtime (``--runtime async``, core.async_migration) — the paper's
*actual* regime, no epoch barrier. Heterogeneous-rate / churn knobs:

  --min-rate/--max-rate   volunteer-speed model: each island's clock rate
                          is drawn from U[min_rate, max_rate] clock-units
                          per tick (0.25..1.0 ~ a phone vs a desktop); an
                          island fires — evolves one autonomous epoch and
                          exchanges — whenever its own clock crosses 1.
  --staleness N           immigrant inbox bound: a delivery parked in an
                          island's on-device inbox is absorbable for N
                          ticks, then expires (slow islands never act on
                          arbitrarily old genomes).
  --churn F               fraction of islands given a seeded down-window:
                          they go available=False mid-run (frozen — a
                          closed tab) and later rejoin with state intact.
  --topology NAME         any registered topology; the fire mask rides the
                          vector ``available`` through core.migration.
  --acceptance NAME       registered immigrant-acceptance policy
                          (core.acceptance): 'always' is the paper's
                          accept-every-PUT ring; 'elitist' replaces the
                          worst resident only when fitter; 'crowding'
                          replaces the *nearest* resident by genome
                          distance; 'dedup' rejects epsilon-clones (the
                          near-identical-elite flood) then falls back to
                          elitist. The host PoolServer mirrors the same
                          policy so device and host pools agree.
  --acceptance-epsilon E  dedup rejection radius (0 = exact clones only).

In both modes a host PoolServer runs alongside with two browser-style
PoolClient volunteers; a HostBridge (sync) or non-blocking AsyncHostBridge
(async — server I/O on a worker thread, exactly-once delivery via the
server's seq cursor) splices them into the device islands' experiment.
The sync demo also runs a StragglerMonitor over simulated heterogeneous
hardware and prints the per-worker work-scale the driver would apply.
"""
import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AcceptanceConfig, AsyncConfig, AsyncHostBridge,
                        EAConfig, HostBridge, MigrationConfig, PoolClient,
                        PoolServer, available_acceptance_policies, make_trap)
from repro.core import async_migration, evolution, island as island_lib, \
    pool as pool_lib
from repro.obs import counters as obs_lib
from repro.obs import trace as obs_trace
from repro.runtime import StragglerMonitor, grow_islands, shrink_islands


def make_volunteers(server, problem, n=2, clients=None):
    volunteers = (clients if clients is not None
                  else [PoolClient(server, uuid=100 + i) for i in range(n)])
    vol_rng = np.random.default_rng(7)

    def volunteer_round():
        """Each volunteer hill-climbs a random genome a little and PUTs it
        (a browser tab doing one autonomous epoch)."""
        for v in volunteers:
            got = v.get_random()
            g = (got[0].copy() if got is not None
                 else vol_rng.integers(0, 2, problem.genome.length)
                 .astype(np.int8))
            flip = vol_rng.integers(0, g.size, 4)
            g[flip] = 1  # volunteers push toward the all-ones optimum
            f = float(problem.evaluate(problem.consts, g[None])[0])
            v.put(g, f)

    return volunteers, volunteer_round


def run_sync():
    problem = make_trap(n_traps=20, l=4)
    cfg = EAConfig(max_pop=128, min_pop=64, generations_per_epoch=50,
                   mutation_rate=1.0 / 80)
    mig = MigrationConfig(pool_capacity=64)
    rng = jax.random.key(0)

    k, rng = jax.random.split(rng)
    islands = island_lib.init_islands(k, 8, problem, cfg)
    pool = pool_lib.pool_init(mig.pool_capacity, problem.genome)
    mon = StragglerMonitor(threshold=2.0)

    # host side: a REST-semantics PoolServer, two volunteer clients and the
    # bridge that lets them join the device islands' experiment
    server = PoolServer(capacity=256, seed=1)
    volunteers, volunteer_round = make_volunteers(server, problem)
    bridge = HostBridge(server, every=1, pull=2)

    # one jitted step; up/e are traced args so epochs reuse a single compile
    epoch = jax.jit(lambda i, q, kk, up, e: evolution.epoch_step(
        i, q, kk, problem, cfg, mig, False, up, epoch=e))

    for e in range(1, 16):
        up = not (3 <= e < 6)
        if up:
            server.revive()
        else:
            server.kill()
        k, rng = jax.random.split(rng)
        t0 = time.perf_counter()
        islands, pool = epoch(islands, pool, k, up, jnp.int32(e))
        # sync first so the server is seeded with the device best before the
        # volunteers GET — a cold-start GET against an empty-but-up server
        # would otherwise read as a lost XHR
        pool = bridge.sync(pool, e)
        if up:
            volunteer_round()
        mon.record(0, time.perf_counter() - t0)

        if e == 8:
            k, rng = jax.random.split(rng)
            islands = grow_islands(islands, 4, problem, cfg, pool, k)
            note = "+4 volunteers joined (pool-seeded)"
        elif e == 12:
            islands = shrink_islands(islands, 6)
            note = "-6 volunteers left (pool keeps their work)"
        else:
            note = ""
        best = float(islands.best_fitness.max())
        print(f"epoch {e:2d} [{'server UP ' if up else 'server DOWN'}] "
              f"islands={islands.pop.shape[0]:2d} best={best:5.1f}/40 "
              f"pool={int(pool.count):2d} bridge={bridge.stats()} {note}")
        if best >= 40.0:
            print("solution found — experiment over")
            break
    print(f"volunteer lost XHRs: "
          f"{[(v.uuid, v.lost_puts + v.lost_gets) for v in volunteers]}")

    # straggler demo: simulated heterogeneous fleet
    print("\nstraggler mitigation (simulated heterogeneous volunteers):")
    mon2 = StragglerMonitor(threshold=1.5)
    speeds = {0: 1.0, 1: 1.1, 2: 0.9, 3: 4.0}   # worker 3 is a phone
    for _ in range(8):
        for w, s in speeds.items():
            mon2.record(w, s)
    for w in speeds:
        print(f"  worker {w}: work_scale={mon2.work_scale(w):.2f} "
              f"{'<- straggler: fewer generations/epoch' if w in mon2.stragglers() else ''}")


def run_async(args):
    """The asynchronous runtime demo: heterogeneous clocks + seeded churn +
    a non-blocking host bridge, every island at its own pace."""
    problem = make_trap(n_traps=20, l=4)
    cfg = EAConfig(max_pop=128, min_pop=64, generations_per_epoch=50,
                   mutation_rate=1.0 / 80)
    acc = AcceptanceConfig(policy=args.acceptance,
                           epsilon=args.acceptance_epsilon)
    mig = MigrationConfig(pool_capacity=64, topology=args.topology,
                          acceptance=acc)
    acfg = AsyncConfig(min_rate=args.min_rate, max_rate=args.max_rate,
                       staleness=args.staleness, churn_fraction=args.churn,
                       seed=args.seed)
    n, ticks = 8, args.ticks
    rng = jax.random.key(args.seed)
    k_init, rng = jax.random.split(rng)
    islands = island_lib.init_islands(k_init, n, problem, cfg)
    pool = pool_lib.pool_init(mig.pool_capacity, problem.genome)
    astate = async_migration.init_async_state(
        jax.random.fold_in(k_init, 7), n, acfg, ticks, problem.genome)
    print("volunteer speeds:", np.round(np.asarray(astate.rate), 2))
    down = [(int(s), int(e)) for s, e in
            zip(np.asarray(astate.down_start), np.asarray(astate.down_end))
            if int(s) <= ticks]
    print(f"churn windows (down..rejoin): {down or 'none'}")

    # the server mirrors the device acceptance policy (numpy host_accept)
    if args.server:
        # networked mode: every participant speaks the JSON wire protocol
        # to a running `python -m repro.server` service; each volunteer
        # gets its own keep-alive connection (its own browser tab)
        from repro.server import RemotePoolServer
        ensure = RemotePoolServer(args.server, experiment=args.experiment,
                                  client_id="volunteer-sim")
        ensure.create(capacity=256, seed=1,
                      acceptance=acc.policy, epsilon=acc.epsilon)
        server = ensure
        clients = [PoolClient(
            RemotePoolServer(args.server, experiment=args.experiment,
                             client_id=f"volunteer-{i}"), uuid=100 + i)
            for i in range(2)]
        volunteers, volunteer_round = make_volunteers(
            server, problem, clients=clients)
        bridge = AsyncHostBridge(args.server, pull=4, acceptance=acc,
                                 experiment=args.experiment,
                                 cursor_id="volunteer-sim-bridge")
    else:
        server = PoolServer(capacity=256, seed=1,
                            acceptance=acc if acc.policy != "always" else None)
        volunteers, volunteer_round = make_volunteers(server, problem)
        bridge = AsyncHostBridge(server, pull=4, acceptance=acc)

    step = jax.jit(partial(async_migration.async_step, problem=problem,
                           cfg=cfg, mig=mig, acfg=acfg, w2=False))
    obs = obs_lib.init_obs(n) if args.obs_json else None
    t = 0
    for t in range(1, ticks + 1):
        rng, k = jax.random.split(rng)
        with obs_trace.span("driver.tick", tick=t):
            if obs is not None:
                islands, pool, astate, obs = step(islands, pool, astate, k,
                                                  tick=t, obs=obs)
            else:
                islands, pool, astate = step(islands, pool, astate, k, tick=t)
        pool = bridge.sync(pool, t)     # non-blocking: never waits on server
        volunteer_round()
        fires = np.asarray(astate.fires)
        up_now = ~((np.asarray(astate.down_start) <= t)
                   & (t < np.asarray(astate.down_end)))
        best = float(islands.best_fitness.max())
        print(f"tick {t:2d} best={best:5.1f}/40 pool={int(pool.count):2d} "
              f"alive={int(up_now.sum())}/{n} fires/island={fires.tolist()} "
              f"bridge={bridge.stats()}")
        if best >= 40.0:
            print("solution found — experiment over")
            break
    pool = bridge.flush(pool)
    bridge.close()
    print(f"total island-epochs fired: {int(np.asarray(astate.fires).sum())} "
          f"of {n * max(t, 1)} synchronous equivalents; "
          f"bridge={bridge.stats()}")
    if obs is not None:
        harvest = obs_lib.harvest(obs)
        tot = harvest["totals"]
        balanced = tot["delivered"] == tot["accepted"] + tot["rejected"]
        with open(args.obs_json, "w") as fh:
            json.dump(harvest, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"obs ledger: fired={tot['fired']} "
              f"delivered={tot['delivered']} accepted={tot['accepted']} "
              f"rejected={tot['rejected']} churn_down={tot['churn_down']} "
              f"balanced={'OK' if balanced else 'BROKEN'} "
              f"-> {args.obs_json}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runtime", choices=("sync", "async"), default="sync")
    ap.add_argument("--min-rate", type=float, default=0.25)
    ap.add_argument("--max-rate", type=float, default=1.0)
    ap.add_argument("--staleness", type=int, default=3)
    ap.add_argument("--churn", type=float, default=0.4)
    ap.add_argument("--topology", default="pool")
    ap.add_argument("--acceptance", default="always",
                    choices=available_acceptance_policies())
    ap.add_argument("--acceptance-epsilon", type=float, default=0.0)
    ap.add_argument("--ticks", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--server", default=None, metavar="URL",
                    help="async mode only: join a networked "
                         "`python -m repro.server` service at URL over the "
                         "JSON wire protocol instead of an in-process pool")
    ap.add_argument("--experiment", default="volunteer-sim",
                    help="experiment namespace on the networked server")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record host spans (bridge/pool/driver) and write "
                         "a Chrome trace-event JSON here — open in Perfetto")
    ap.add_argument("--obs-json", default=None, metavar="PATH",
                    help="async mode only: carry on-device ObsCounters "
                         "through every tick and write the harvested "
                         "ledger (delivered == accepted + rejected) here")
    args = ap.parse_args()
    if args.server and args.runtime != "async":
        ap.error("--server requires --runtime async")
    if args.obs_json and args.runtime != "async":
        ap.error("--obs-json requires --runtime async")
    tracer = obs_trace.enable() if args.trace else None
    try:
        if args.runtime == "async":
            run_async(args)
        else:
            run_sync()
    finally:
        if tracer is not None:
            tracer.export_chrome(args.trace)
            print(f"wrote Chrome trace ({len(tracer.events())} events) "
                  f"-> {args.trace}")
            obs_trace.disable()


if __name__ == "__main__":
    main()
